"""std::atomic<struct> analogue (paper Figure 2): "lock; copy struct;
unlock" at maximal contention — the machine's shared-rw CS profile.

Shim over the registered ``atomics`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite atomics``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main


def main() -> dict:
    return run_suite_main("atomics", artifact="fig2_atomics")


if __name__ == "__main__":
    main()
