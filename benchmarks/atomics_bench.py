"""std::atomic<struct> analogue (paper Figure 2).

The C++ runtime implements atomic ops on a 20-byte struct by hashing the
address into a mutex table and taking the covering lock; the measured
workload is therefore "lock; copy struct; unlock" at maximal contention —
exactly our machine's shared-rw CS profile with an empty NCS. The
CAS-retry variant (Fig. 2b) adds an optimistic outer retry: modeled by the
same lock path with a small extra local verify cost.
"""
from __future__ import annotations

from benchmarks.common import Timer, emit, save
from repro.core.sim.api import bench_lock
from repro.core.sim.machine import CostModel

ALGS = ("reciprocating", "ticket", "mcs", "clh", "hemlock", "ttas")
THREADS = (1, 2, 4, 8, 16, 24)


def main() -> dict:
    rows = {}
    for alg in ALGS:
        series = []
        for t in THREADS:
            cost = CostModel(n_nodes=2 if t > 8 else 1)
            with Timer() as tm:
                r = bench_lock(alg, t, n_steps=20_000, ncs_max=0,
                               cs_shared="rw", cost=cost, n_replicas=2)
            series.append({"threads": t, "throughput": r.throughput})
            emit(f"atomics_xchg/{alg}/T{t}",
                 tm.dt / max(r.episodes, 1) * 1e6,
                 f"thr={r.throughput:.3f}/kcyc")
        rows[alg] = series
    save("fig2_atomics", rows)
    return rows


if __name__ == "__main__":
    main()
