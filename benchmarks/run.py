"""Benchmark harness entry point: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME]]``
prints ``name,us_per_call,derived`` CSV rows and writes JSON artifacts to
``benchmarks/artifacts/``.

Sections -> paper artifacts:
  mutexbench   Fig. 1a/1b  (thread sweep, maximal contention + random NCS)
  atomics      Fig. 2      (lock-striped std::atomic<struct>)
  kvstore      Fig. 3      (LevelDB readrandom analogue, read-only CS)
  coherence    Table 1     (invalidations / misses per episode)
  fairness     Table 2/§9  (palindromic cycle, 2x bound, §9.4 mitigation)
  residency    App. C      (Jensen/decay model)
  scheduler    (beyond-paper) reciprocating continuous-batching admission
  kernels      (beyond-paper) serpentine DMA savings
  roofline     §Roofline   (dry-run artifact aggregation)
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (atomics_bench, coherence_bench, fairness_bench,
                            kernel_bench, kvstore_bench, mutexbench,
                            residency_bench, roofline, scheduler_bench)
    sections = {
        "coherence": coherence_bench.main,
        "fairness": fairness_bench.main,
        "residency": residency_bench.main,
        "kernels": kernel_bench.main,
        "scheduler": scheduler_bench.main,
        "kvstore": kvstore_bench.main,
        "atomics": atomics_bench.main,
        "mutexbench": mutexbench.main,
        "roofline": roofline.main,
    }
    chosen = ([s for s in args.only.split(",") if s] if args.only
              else list(sections))
    print("name,us_per_call,derived")
    for name in chosen:
        print(f"# === {name} ===", flush=True)
        sections[name]()


if __name__ == "__main__":
    main()
