"""Legacy benchmark driver — now a shim over the ``repro.bench`` CLI.

``PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME]]`` runs the
named suites (default: all legacy sections) through the registry, printing
the historical ``name,us_per_call,derived`` CSV rows and writing each
suite's result document to ``benchmarks/artifacts/``.

Prefer the first-class CLI::

    PYTHONPATH=src python -m repro.bench run --suite paper \\
        --out BENCH_paper.json
"""
from __future__ import annotations

import argparse

from benchmarks.common import run_suite_main

# legacy section name -> (suite, artifact name)
SECTIONS = {
    "coherence": ("coherence", "table1_coherence"),
    "fairness": ("fairness", "fairness"),
    "residency": ("residency", "appc_residency"),
    "kernels": ("kernels", "kernel_serpentine"),
    "scheduler": ("scheduler", "scheduler_policies"),
    "serve": ("serve", "serve_policies"),
    "kvstore": ("kvstore", "fig3_kvstore"),
    "atomics": ("atomics", "fig2_atomics"),
    "mutexbench": ("mutexbench", "mutexbench"),
    "topology": ("topology", "topology_grid"),
    "roofline": ("roofline", "roofline_table"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    chosen = ([s for s in args.only.split(",") if s] if args.only
              else list(SECTIONS))
    print("name,us_per_call,derived")
    for name in chosen:
        suite, artifact = SECTIONS[name]
        print(f"# === {name} ===", flush=True)
        run_suite_main(suite, artifact=artifact)


if __name__ == "__main__":
    main()
