"""Table 1 reproduction: coherence traffic per contended episode.

Degenerate local CS (the paper's l2d_cache_inval experiment), T=10,
sustained contention. Paper's numbers: Reciprocating 4 (invalidations),
CLH 5, MCS 6, Ticket ~T; max remote misses RL=2.
"""
from __future__ import annotations

from benchmarks.common import Timer, emit, save
from repro.core.sim.api import bench_lock
from repro.core.sim.machine import CostModel

PAPER = {"reciprocating": 4, "clh": 5, "mcs": 6, "hemlock": 5,
         "ticket": 10, "anderson": None, "ttas": None, "retrograde": None}


def main() -> dict:
    out = {}
    for alg, paper_val in PAPER.items():
        with Timer() as tm:
            r = bench_lock(alg, 10, n_steps=24_000, cs_shared=False,
                           cost=CostModel(n_nodes=1), n_replicas=2)
            r2 = bench_lock(alg, 10, n_steps=24_000, cs_shared=False,
                            cost=CostModel(n_nodes=2), n_replicas=2)
        out[alg] = {
            "miss_per_episode": round(r.miss_per_episode, 2),
            "inval_per_episode": round(r.inval_per_episode, 2),
            "remote_per_episode_numa": round(r2.remote_per_episode, 2),
            "paper_invalidations": paper_val,
        }
        emit(f"coherence/{alg}", tm.dt / max(r.episodes, 1) * 1e6,
             f"miss/ep={r.miss_per_episode:.2f} paper={paper_val}")
    save("table1_coherence", out)
    return out


if __name__ == "__main__":
    main()
