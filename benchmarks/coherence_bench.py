"""Table 1 reproduction: coherence traffic per contended episode
(degenerate local CS, sustained contention).

Shim over the registered ``coherence`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite coherence``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main


def main() -> dict:
    return run_suite_main("coherence", artifact="table1_coherence")


if __name__ == "__main__":
    main()
