"""Shared helpers for the legacy benchmark shims.

The benchmark logic itself lives in ``repro.bench`` (registry-driven
suites); every ``benchmarks/*_bench.py`` script is now a thin shim that
runs its registered suite and drops the result document in
``benchmarks/artifacts/``. Artifact *paths* are kept, but payloads are now
full ``repro.bench/v1`` documents (the old ad-hoc row dicts are gone, and
``mutexbench`` saves one document instead of the two per-figure files).
Set ``REPRO_BENCH_QUICK=1`` to shrink the grids for smoke runs.
"""
from __future__ import annotations

import json
import os
import time

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def save(name: str, payload) -> None:
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0


def run_suite_main(suite: str, artifact: str | None = None) -> dict:
    """Run a registered ``repro.bench`` suite and save its result document
    as a legacy artifact. Returns the document."""
    from repro.bench import BenchConfig, run_suite
    quick = os.environ.get("REPRO_BENCH_QUICK", "").strip().lower()
    cfg = BenchConfig(quick=quick in ("1", "true", "yes", "on"))
    doc = run_suite(suite, cfg)
    save(artifact or suite, doc)
    return doc
