"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def save(name: str, payload) -> None:
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
