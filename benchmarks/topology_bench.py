"""Machine-topology sweep: every lock across SMP / NUMA / clustered-CCX
machine models, remote-miss scaling vs node count, placement sensitivity
(DESIGN.md §L1).

Shim over the registered ``topology`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite topology``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main


def main() -> dict:
    return run_suite_main("topology", artifact="topology_grid")


if __name__ == "__main__":
    main()
