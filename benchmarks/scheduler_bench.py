"""Serving-scheduler benchmark: reciprocating admission vs FIFO vs LIFO
(beyond-paper systems adaptation, DESIGN.md §L3).

Workload: Poisson arrivals of requests drawn from shared-prefix families;
fixed KV-block pool with LRU decay. Metrics: prefix-cache hit rate,
throughput, p50/p99 queueing wait (LIFO's starvation shows in p99).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, save
from repro.serve.scheduler import ContinuousBatcher, Request


def drive(policy: str, *, n_req: int = 600, mean_gap: float = 14.0,
          families: int = 64, pool: int = 96, seed: int = 0) -> dict:
    """Bursty shared-prefix workload: a family arrives as a burst of 2-6
    requests close together (users iterating on one prompt) — the regime
    where admission order interacts with prefix residency."""
    sched = ContinuousBatcher(policy=policy, max_batch=4, pool_blocks=pool,
                              seed=seed)
    rng = np.random.default_rng(seed)
    t, i = 0.0, 0
    while i < n_req:
        t += float(rng.exponential(mean_gap))
        fam = int(rng.integers(0, families))
        for _ in range(int(rng.integers(2, 7))):
            if i >= n_req:
                break
            sched.submit(Request(rid=i, arrival=t + float(rng.exponential(2.0)),
                                 prefix_id=fam,
                                 prefix_blocks=16, prompt_blocks=2,
                                 decode_tokens=int(rng.integers(4, 16))))
            i += 1
    sched.drain()
    return sched.stats.summary()


def main() -> dict:
    out = {}
    for policy in ("fifo", "lifo", "reciprocating",
                   "reciprocating_mitigated"):
        agg = {}
        with Timer() as tm:
            for seed in range(3):
                s = drive(policy, seed=seed)
                for k, v in s.items():
                    agg.setdefault(k, []).append(v)
        out[policy] = {k: float(np.mean(v)) for k, v in agg.items()}
        emit(f"scheduler/{policy}", tm.dt / 3 * 1e6 / 600,
             f"hit={out[policy]['prefix_hit_rate']:.3f} "
             f"p99wait={out[policy]['p99_wait']:.1f} "
             f"maxwait={out[policy]['max_wait']:.0f} "
             f"thr={out[policy]['throughput_rps']:.3f}")
    save("scheduler_policies", out)
    return out


if __name__ == "__main__":
    main()
