"""Serving-scheduler benchmark: reciprocating admission vs FIFO vs LIFO
(beyond-paper systems adaptation, DESIGN.md §L3).

Shim over the registered ``scheduler`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite scheduler``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main
from repro.bench.suites import scheduler_drive as drive  # noqa: F401


def main() -> dict:
    return run_suite_main("scheduler", artifact="scheduler_policies")


if __name__ == "__main__":
    main()
