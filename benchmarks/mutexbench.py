"""MutexBench — paper Figure 1 (a: maximal contention, b: random NCS).

Shim over the registered ``mutexbench`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite mutexbench``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main


def main() -> dict:
    return run_suite_main("mutexbench")


if __name__ == "__main__":
    main()
