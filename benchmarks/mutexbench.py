"""MutexBench — paper Figure 1 (a: maximal contention, b: random NCS).

Thread sweep x lock algorithm on the JAX coherence machine; reports
aggregate throughput (episodes per kilocycle), misses/episode and
fairness. NUMA onset is modeled at >half the thread sweep (2 nodes),
mirroring the paper's 2-socket X5-2 where threads spill to the second
socket above 18.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, save
from repro.core.sim.api import bench_lock
from repro.core.sim.machine import CostModel

ALGS = ("reciprocating", "ticket", "mcs", "clh", "hemlock", "ttas",
        "anderson", "retrograde")
THREADS = (1, 2, 4, 8, 16, 24, 32)


def run_figure(ncs_max: int, tag: str, n_steps: int = 24_000) -> dict:
    rows = {}
    for alg in ALGS:
        series = []
        for t in THREADS:
            cost = CostModel(n_nodes=2 if t > 8 else 1)
            with Timer() as tm:
                r = bench_lock(alg, t, n_steps=n_steps, ncs_max=ncs_max,
                               cost=cost, n_replicas=2)
            series.append({
                "threads": t, "throughput": r.throughput,
                "miss_per_episode": r.miss_per_episode,
                "latency": r.latency, "unfairness": r.unfairness,
                "wall_s": round(tm.dt, 2),
            })
            emit(f"mutexbench_{tag}/{alg}/T{t}",
                 tm.dt / max(r.episodes, 1) * 1e6,
                 f"thr={r.throughput:.3f}/kcyc miss/ep={r.miss_per_episode:.2f}")
        rows[alg] = series
    save(f"mutexbench_{tag}", rows)
    return rows


def main() -> dict:
    fig1a = run_figure(ncs_max=0, tag="max_contention")
    fig1b = run_figure(ncs_max=250, tag="random_ncs")

    # headline check mirroring the paper's conclusions at high contention
    t = THREADS[-2]
    idx = THREADS.index(t)
    rl = fig1a["reciprocating"][idx]["throughput"]
    rank = {a: fig1a[a][idx]["throughput"] for a in ALGS}
    best = max(rank, key=rank.get)
    print(f"# Fig1a @T={t}: best={best} "
          f"(reciprocating {'WINS' if best == 'reciprocating' else 'loses'};"
          f" {rl:.3f}/kcyc)")
    return {"fig1a": fig1a, "fig1b": fig1b}


if __name__ == "__main__":
    main()
