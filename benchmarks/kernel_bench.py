"""Kernel benchmarks: serpentine-vs-ascending structural DMA accounting
for the assigned architectures' attention shapes.

Shim over the registered ``kernels`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite kernels``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main


def main() -> dict:
    return run_suite_main("kernels", artifact="kernel_serpentine")


if __name__ == "__main__":
    main()
