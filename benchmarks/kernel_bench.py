"""Kernel benchmarks: serpentine-vs-ascending structural DMA accounting for
the assigned architectures' attention shapes, plus interpret-mode
correctness timing (wall time on CPU interpret is NOT a TPU metric — the
HBM-bytes column is the roofline-relevant output)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, save
from repro.configs import get_config
from repro.kernels.flash_attention import serpentine_savings

# representative (arch, Sq, Sk, block) attention instances
CASES = [
    ("granite-3-2b", 4096, 4096, 128),
    ("mixtral-8x7b", 4096, 4096, 128),       # sliding window handled in-mask
    ("starcoder2-7b", 32768, 32768, 256),
    ("deepseek-v2-236b", 4096, 4096, 128),
    ("whisper-large-v3", 4096, 1536, 128),
]


def main() -> dict:
    out = {}
    for arch, sq, sk, blk in CASES:
        cfg = get_config(arch)
        n_q, n_kv = sq // blk, sk // blk
        s = serpentine_savings(n_q, n_kv)
        kv_heads = max(cfg.n_kv_heads, 1)
        block_bytes = blk * cfg.hd * 2 * 2            # k+v, bf16
        saved = (s["ascending"] - s["serpentine"]) * block_bytes * kv_heads
        out[arch] = {
            "grid": [n_q, n_kv], **s,
            "hbm_bytes_saved_per_batch_row": int(saved),
        }
        emit(f"kernel/serpentine/{arch}", 0.0,
             f"saved={s['saved_fraction']*100:.1f}% of KV fetches "
             f"({saved/1e6:.2f} MB/row)")
    save("kernel_serpentine", out)
    return out


if __name__ == "__main__":
    main()
