"""Fairness / palindromic-schedule benchmarks (paper §9, Table 2) plus
bounded-bypass histograms over the ``core.admission`` policies.

Shim over the registered ``fairness`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite fairness``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main


def main() -> dict:
    return run_suite_main("fairness")


if __name__ == "__main__":
    main()
