"""Fairness / palindromic-schedule benchmarks (paper §9, Table 2).

* Table-2 cycle detection on the reference interleaver (exact) and on the
  timed machine's admission log.
* Long-term unfairness (max/min episodes): reciprocating ~2x bimodal;
  ticket ~1x; the §9.4 mitigation restores ~1x while preserving segments.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, save
from repro.core.locks.reference import ALGORITHMS
from repro.core.sim.api import bench_lock
from repro.core.sim.interleave import run as ref_run
from repro.core.admission import ReciprocatingQueue


def admission_unfairness_mitigated(seed: int = 0, n: int = 4000) -> float:
    """§9.4: random-without-replacement intra-segment order."""
    q = ReciprocatingQueue(seed, mitigate=True)
    rng = np.random.default_rng(seed)
    counts = np.zeros(5, int)
    live = []
    for i in range(n):
        tid = i % 5
        q.push(tid)
        got = q.pop()
        if got is not None:
            counts[got] += 1
    return float(counts.max() / max(counts.min(), 1))


def main() -> dict:
    out = {}
    with Timer() as tm:
        r = ref_run(ALGORITHMS["reciprocating"](5), 5, n_ops=8000,
                    policy="rr")
    cyc = r.cycle()
    out["table2_cycle"] = cyc
    out["table2_cycle_str"] = "".join("ABCDE"[t] for t in cyc) if cyc else None
    out["table2_counts"] = sorted(cyc.count(t) for t in range(5)) if cyc else None
    out["ref_unfairness"] = r.unfairness()
    emit("fairness/table2_cycle", tm.dt * 1e6 / 8000,
         f"cycle={out['table2_cycle_str']} unfair={r.unfairness():.2f}")

    machine = {}
    for alg in ("reciprocating", "ticket", "retrograde"):
        b = bench_lock(alg, 5, n_steps=20_000, n_replicas=2)
        machine[alg] = round(b.unfairness, 3)
        emit(f"fairness/machine_{alg}", 0.0, f"unfair={b.unfairness:.2f}")
    out["machine_unfairness"] = machine

    out["mitigated_unfairness"] = round(admission_unfairness_mitigated(), 3)
    emit("fairness/mitigated", 0.0,
         f"unfair={out['mitigated_unfairness']:.2f}")
    save("fairness", out)
    return out


if __name__ == "__main__":
    main()
