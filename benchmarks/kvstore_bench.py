"""LevelDB-readrandom analogue (paper Figure 3): coarse lock over
read-only lookups, random key-gen NCS.

Shim over the registered ``kvstore`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite kvstore``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main


def main() -> dict:
    return run_suite_main("kvstore", artifact="fig3_kvstore")


if __name__ == "__main__":
    main()
