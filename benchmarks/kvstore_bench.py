"""LevelDB-readrandom analogue (paper Figure 3).

Coarse-grained lock protecting a KV store: CS = read-only lookups (two
shared-line loads — reads keep lines Shared, so the handoff dominates);
NCS = key generation + hashing (random local work). Thread sweep mirrors
Fig. 3's shape.
"""
from __future__ import annotations

from benchmarks.common import Timer, emit, save
from repro.core.sim.api import bench_lock
from repro.core.sim.machine import CostModel

ALGS = ("reciprocating", "ticket", "mcs", "clh", "hemlock")
THREADS = (1, 2, 4, 8, 16, 24)


def main() -> dict:
    rows = {}
    for alg in ALGS:
        series = []
        for t in THREADS:
            cost = CostModel(n_nodes=2 if t > 8 else 1)
            with Timer() as tm:
                r = bench_lock(alg, t, n_steps=20_000, ncs_max=60,
                               cs_shared="ro", cost=cost, n_replicas=2)
            series.append({"threads": t, "throughput": r.throughput,
                           "latency": r.latency})
            emit(f"kvstore/{alg}/T{t}", tm.dt / max(r.episodes, 1) * 1e6,
                 f"thr={r.throughput:.3f}/kcyc")
        rows[alg] = series
    save("fig3_kvstore", rows)
    return rows


if __name__ == "__main__":
    main()
