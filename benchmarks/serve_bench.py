"""Serving-engine benchmark: policy × offered-load sweep on the unified
continuous-batching core + paged-KV pool, and the model-backed engine
smoke (docs/SERVING.md §6).

Shim over the registered ``serve`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite serve``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main
from repro.bench.suites import scheduler_drive as drive  # noqa: F401


def main() -> dict:
    return run_suite_main("serve", artifact="serve_policies")


if __name__ == "__main__":
    main()
