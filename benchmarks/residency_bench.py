"""App. C reproduction: palindromic schedules and residual cache residency.

Model: T parties share an LLC; while a party waits, its residency decays
exponentially (half-life lambda). Aggregate residual residency at service
time under FIFO round-robin vs the palindrome (sawtooth) schedule: Jensen's
inequality (Residual is convex in the waiting gap) => palindrome >= FIFO
for EVERY party, with disparity across parties (the paper's second-order
unfairness). Also computes the serving-scheduler analogue numbers.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save


def schedule_residency(schedule: list[int], n: int, lam: float,
                       cycles: int = 200) -> np.ndarray:
    """Mean residual residency exp(-gap*lam) per party under a repeating
    admission schedule."""
    last = {t: None for t in range(n)}
    acc = {t: [] for t in range(n)}
    step = 0
    for _ in range(cycles):
        for t in schedule:
            if last[t] is not None:
                acc[t].append(np.exp(-(step - last[t]) * lam))
            last[t] = step
            step += 1
    return np.array([np.mean(acc[t]) for t in range(n)])


def main() -> dict:
    n, lam = 5, 0.15
    fifo = list(range(n))                        # ABCDE ABCDE
    # App. C analyzes the true palindrome ABCDE-EDCBA: every party served
    # exactly twice per period (same frequency as FIFO), gaps alternate
    # short/long around the same mean -> Jensen gives >= residency for all.
    palin = list(range(n)) + list(reversed(range(n)))
    r_fifo = schedule_residency(fifo, n, lam)
    r_palin = schedule_residency(palin, n, lam)
    out = {
        "lambda": lam,
        "fifo_mean": float(r_fifo.mean()),
        "palindrome_mean": float(r_palin.mean()),
        "fifo_per_party": [round(float(x), 4) for x in r_fifo],
        "palindrome_per_party": [round(float(x), 4) for x in r_palin],
        "palindrome_wins": bool(r_palin.mean() >= r_fifo.mean()),
        "per_party_never_worse": bool((r_palin >= r_fifo - 1e-12).all()),
        "disparity_palindrome": float(r_palin.max() / r_palin.min()),
    }
    emit("residency/jensen", 0.0,
         f"palin={out['palindrome_mean']:.4f} fifo={out['fifo_mean']:.4f} "
         f"wins={out['palindrome_wins']}")

    # sweep decay rates: the palindrome advantage is monotone in lambda
    sweep = {}
    for lam in (0.02, 0.05, 0.1, 0.2, 0.4):
        a = schedule_residency(palin, n, lam).mean()
        b = schedule_residency(fifo, n, lam).mean()
        sweep[lam] = {"palindrome": float(a), "fifo": float(b),
                      "advantage": float(a / b)}
    out["sweep"] = sweep
    save("appc_residency", out)
    return out


if __name__ == "__main__":
    main()
