"""App. C reproduction: palindromic schedules and residual cache
residency (Jensen/decay model).

Shim over the registered ``residency`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite residency``.
"""
from __future__ import annotations

from benchmarks.common import run_suite_main


def main() -> dict:
    return run_suite_main("residency", artifact="appc_residency")


if __name__ == "__main__":
    main()
