"""Roofline report: aggregates the ``repro.launch.dryrun`` artifacts into
the EXPERIMENTS §Roofline table.

Shim over the registered ``roofline`` suite (``repro/bench/suites.py``);
prefer ``PYTHONPATH=src python -m repro.bench run --suite roofline``.
"""
from __future__ import annotations

import os

from benchmarks.common import ART, run_suite_main


def main() -> dict:
    os.environ.setdefault("REPRO_BENCH_ARTIFACTS", ART)
    return run_suite_main("roofline", artifact="roofline_table")


if __name__ == "__main__":
    main()
