"""Roofline report: aggregates the dry-run artifacts into the
EXPERIMENTS.md §Roofline table (single-pod per the assignment; multi-pod
proves the pod axis shards)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART, emit, save


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(ART, f"dryrun_*_{mesh}.json"))):
        d = json.load(open(f))
        if d.get("status") == "ok":
            cells.append(d)
    return cells


def table(mesh: str = "single") -> list[dict]:
    rows = []
    for d in load_cells(mesh):
        t = d["roofline_seconds"]
        total = max(sum(t.values()), 1e-12)
        bound = max(t.values())
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "compute_ms": round(t["compute"] * 1e3, 2),
            "memory_ms": round(t["memory"] * 1e3, 2),
            "collective_ms": round(t["collective"] * 1e3, 2),
            "dominant": d["dominant"],
            "roofline_fraction": round(t["compute"] / bound, 4),
            "useful_flop_ratio": round(d["useful_flop_ratio"], 4),
            "peak_gb": round(d["peak_bytes_per_device"] / 1e9, 2),
            "fits_16gb": d["fits_16gb"],
        })
    return rows


def markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | roofline frac | useful flops | peak GB | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']} | "
            f"{r['memory_ms']} | {r['collective_ms']} | {r['dominant']} | "
            f"{r['roofline_fraction']} | {r['useful_flop_ratio']} | "
            f"{r['peak_gb']} | {'Y' if r['fits_16gb'] else 'N'} |")
    return "\n".join(lines)


def main() -> dict:
    rows = table("single")
    save("roofline_table", rows)
    with open(os.path.join(ART, "roofline_table.md"), "w") as f:
        f.write(markdown(rows) + "\n")
    for r in rows:
        emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"dom={r['dominant']} frac={r['roofline_fraction']} "
             f"fits={r['fits_16gb']}")
    n_ok = len(rows)
    multi = load_cells("multi")
    print(f"# roofline: {n_ok} single-pod cells, {len(multi)} multi-pod "
          f"cells compiled OK")
    return {"rows": rows}


if __name__ == "__main__":
    main()
