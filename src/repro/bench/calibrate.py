"""Fit the sim ``CostModel`` to measured Pallas-backend curves.

The sim reports throughput in episodes per kilocycle (model time); the
measured tier reports episodes per kilo*slice* (schedule time) and per
wall-second. The two are related by a single scale when the cost model
is right: the sim's cycle accounting compresses each measured slice to
the cycles the op *should* cost, so over a (lock x threads) grid

    measured_eps_per_kslice  ~=  scale * sim_eps_per_kcycle(cost_model)

with one global ``scale`` (slices per cycle under the backend's
schedule). The calibration fits ``scale`` by least squares per
candidate cost model, picks the candidate with the lowest mean relative
error, and reports the per-cell fitted-vs-measured error table that
docs/RESULTS.md publishes. A large residual on one lock flags a cost
the model prices wrong (e.g. parking) rather than a bad fit overall.

Full runs sweep a small candidate grid around the default model
(scaling the local/remote miss costs); ``--quick`` fits the default
model only.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.bench.registry import BenchConfig
from repro.core.sim.machine import CostModel

__all__ = ["CalibrationFit", "calibrate", "fit_scale"]


@dataclass
class CalibrationFit:
    scale: float              # kslices per kcycle, least-squares
    rows: list                # per-cell fitted-vs-measured table rows
    mean_rel_err: float
    max_rel_err: float
    cost_label: str           # the winning candidate cost model
    candidates_tried: int


def fit_scale(pairs) -> float:
    """Least-squares ``scale`` for ``measured ~= scale * sim`` over
    ``(measured, sim)`` pairs (closed form, no intercept: zero sim
    throughput must map to zero measured throughput)."""
    num = sum(m * s for m, s in pairs)
    den = sum(s * s for _, s in pairs)
    return num / den if den else 0.0


def _candidates(cfg: BenchConfig) -> list:
    # "uniform" (miss == hit) is the machine an interpret-mode backend
    # actually presents — every slice costs one interpreter step — so on
    # CPU the fitter should select it; on real silicon the miss-priced
    # candidates win. Keeping both in the pool is what makes the
    # calibration a *measurement*, not an assumption.
    base = CostModel()
    uniform = replace(base, local_miss=base.hit, remote_miss=base.hit)
    out = [("default", base), ("uniform", uniform)]
    if cfg.quick:
        return out
    for k in (0.5, 2.0):
        out.append((f"miss x{k:g}", replace(
            base, local_miss=int(base.local_miss * k),
            remote_miss=int(base.remote_miss * k))))
    return out


def _sim_curves(cells, cand: CostModel, cfg: BenchConfig) -> dict:
    """Sim throughput (episodes/kcycle) for every measured (lock, T)
    cell under candidate cost model ``cand`` — through the cached grid
    layer, so repeated calibrations replay from the store."""
    from repro.bench import sweep

    out = {}
    for (alg, t) in cells:
        nn = 2 if t > cfg.numa_above else 1
        r = sweep.bench_cell(alg, t, cfg, ncs_max=0,
                             topology=replace(cand, n_nodes=nn))
        out[(alg, t)] = float(r.throughput)
    return out


def calibrate(measured: dict, cfg: BenchConfig) -> CalibrationFit:
    """Fit against the measured max-contention sweep.

    ``measured`` maps ``(lock, threads) -> measured-cell summary dict``
    (the ``measured_fig1a`` cells from ``bench/measured.py``). Returns
    the winning fit with its per-cell error rows.

    Only *contended* cells (threads >= 2) enter the fit: at T=1 the sim
    collapses an episode to a handful of always-hit cycles, so
    uncontended throughput is orders of magnitude above every contended
    cell and a least-squares scale would fit nothing but that outlier —
    and the paper's figures are about contention anyway.
    """
    keys = sorted((k for k in measured if k[1] >= 2),
                  key=lambda k: (k[0], k[1]))
    if len(keys) < 2:                 # degenerate grid (e.g. threads=(1,))
        keys = sorted(measured, key=lambda k: (k[0], k[1]))
    best = None
    tried = 0
    for label, cand in _candidates(cfg):
        tried += 1
        sim = _sim_curves(keys, cand, cfg)
        pairs = [(measured[k]["episodes_per_kslice"], sim[k])
                 for k in keys]
        scale = fit_scale(pairs)
        rows, errs = [], []
        for k, (m, s) in zip(keys, pairs):
            fitted = scale * s
            rel = abs(fitted - m) / m if m else 0.0
            errs.append(rel)
            rows.append({
                "lock": k[0], "threads": k[1],
                "measured_eps_per_kslice": round(m, 4),
                "sim_eps_per_kcycle": round(s, 4),
                "fitted": round(fitted, 4),
                "rel_err": round(rel, 4),
            })
        mean_err = sum(errs) / len(errs) if errs else 0.0
        fit = CalibrationFit(
            scale=round(scale, 6), rows=rows,
            mean_rel_err=round(mean_err, 4),
            max_rel_err=round(max(errs), 4) if errs else 0.0,
            cost_label=label, candidates_tried=0)
        if best is None or fit.mean_rel_err < best.mean_rel_err:
            best = fit
    best.candidates_tried = tried
    return best
