"""Sweep driver: ``SimEngine`` grids over the lock simulator.

The unit of work is a *cell* — one (lock, thread count, machine,
workload) grid point. Cells run through the per-lock ``SimEngine``
sessions (``core/sim/engine.py``): thread count and workload fix the
compiled shape, while the seed and topology axes are stacked
``LoweredCost`` data vmapped through **one jit per shape** — Table 1's
1-node and 2-node variants, and the whole SMP/NUMA/CCX grid of the
``topology`` suite, share a compile (the engine's ``compiles`` counter
is what the CI batching assertion watches).

Cells are served through the content-addressed experiment cache
(``bench/cache.py``): ``cached_grid`` keys every cell of a grid call on
the canonical (program, machine, scheduler, workload, seeds) hash, and
an all-hit grid reconstructs its ``GridResult`` from the store with
zero XLA traces. Any miss runs the *whole* grid once (preserving the
one-jit batching contract) and stores every cell.

Also here: the admission-queue bypass instrumentation (paper §2 bounded
bypass, §9.4 mitigation) driven against ``repro.core.admission`` policies,
and the reference-interleaver fairness probes (Table 2).
"""
from __future__ import annotations

import time
import warnings
from dataclasses import replace

import numpy as np

from repro.bench import cache as cachemod
from repro.bench.registry import BenchConfig, emit
from repro.core.admission import POLICIES, max_bypass_bound
from repro.core.locks.programs import PROGRAMS
from repro.core.sim.engine import (
    GridCell, GridResult, SimEngine, Workload, cost_label, resolve_workload,
    sched_label, session, _lower_host, _lower_sched_host,
)
from repro.core.sim.machine import CostModel, MachineState

ALL_ALGS = tuple(sorted(PROGRAMS))

# Point metrics exported into sweep series (BenchResult field -> key).
POINT_METRICS = ("throughput", "miss_per_episode", "inval_per_episode",
                 "remote_per_episode", "latency", "unfairness")


def run_grid(prog, n_threads: int, n_steps: int, seeds, n_nodes,
             cost: CostModel = CostModel()) -> MachineState:  # noqa: B008
    """Deprecated shim: elementwise (seed, n_nodes) batch in one jit.
    Per-point cost models are now built with ``dataclasses.replace`` —
    every ``CostModel`` field rides through — and lowered to the stacked
    matrix batch by the engine. Use ``SimEngine.grid`` directly."""
    warnings.warn(
        "run_grid is deprecated; use repro.core.sim.engine.SimEngine"
        "(...).grid(seeds=..., topologies=[...])",
        DeprecationWarning, stacklevel=2)
    eng = SimEngine(prog, n_threads=n_threads,
                    workload=Workload(n_steps=n_steps))
    lows = [replace(cost, n_nodes=int(nn)) for nn in np.asarray(n_nodes)]
    slo = _lower_sched_host(None, n_threads)
    return eng._run_batch([int(s) for s in np.asarray(seeds)],
                          [_lower_host(c, n_threads) for c in lows],
                          [slo] * len(lows), eng.workload, n_threads)


def cached_grid(alg: str, *, seeds, topologies=None, workloads=None,
                schedulers=None, threads=None) -> GridResult:
    """``session(alg).grid(...)`` fronted by the experiment cache.

    Computes the content key of every cell the grid *would* produce (in
    the engine's exact cell order: threads-major, then workload, then
    topology, then scheduler). All hits -> a ``GridResult`` rebuilt from
    the store, ``compiles == 0``, no simulation. Any miss -> one real
    grid call (the full batch, so the one-jit-per-shape contract and its
    compile accounting are untouched) whose cells are all stored."""
    eng = session(alg)
    store = cachemod.get_cache()
    if not store.enabled:
        return eng.grid(seeds=seeds, topologies=topologies,
                        workloads=workloads, schedulers=schedulers,
                        threads=threads)
    seeds = [int(s) for s in seeds]
    topos = (list(topologies) if topologies is not None
             else [eng.topology])
    schs = (list(schedulers) if schedulers is not None
            else [eng.scheduler])
    wls = [resolve_workload(w) if w is not None else eng.workload
           for w in (workloads if workloads is not None
                     else [eng.workload])]
    ts = list(threads) if threads is not None else [eng.n_threads]
    plan = []      # (key, n_threads, workload, topo label, sched label)
    for T in ts:
        lows = [(cost_label(c), _lower_host(c, T)) for c in topos]
        slos = [(sched_label(s), _lower_sched_host(s, T)) for s in schs]
        for wl in wls:
            fp = cachemod.program_fingerprint(eng.program(T, wl))
            for lab, lo in lows:
                for slab, sl in slos:
                    plan.append((cachemod.cell_key(fp, T, wl, lo, sl,
                                                   seeds),
                                 T, wl, lab, slab))
    found = [store.get(key) for key, *_ in plan]
    if all(doc is not None for doc in found):
        store.stats.hits += len(plan)
        cells = tuple(
            GridCell(lock=eng.name, n_threads=T, topology=lab,
                     workload=wl.name, scheduler=slab,
                     result=cachemod.result_from_doc(doc))
            for doc, (_, T, wl, lab, slab) in zip(found, plan))
        return GridResult(cells, 0)
    store.stats.misses += len(plan)
    g = eng.grid(seeds=seeds, topologies=topos, workloads=wls,
                 schedulers=schs, threads=ts)
    for (key, *_), cell in zip(plan, g.cells):
        store.put(key, cachemod.result_to_doc(cell.result))
    return g


def default_machine(cfg: BenchConfig, n_threads: int) -> CostModel:
    """The historical default machine for a cell: flat, 2 NUMA nodes
    above ``cfg.numa_above`` threads."""
    return CostModel(n_nodes=2 if n_threads > cfg.numa_above else 1)


def bench_cell(alg: str, n_threads: int, cfg: BenchConfig, *,
               ncs_max: int = 0, cs_shared=True, n_nodes=None,
               topology=None):
    """One cell through the shared per-lock session; returns BenchResult.
    ``topology`` (a ``Topology``/``CostModel``/preset name) overrides the
    flat ``n_nodes`` default."""
    if topology is None:
        topology = (default_machine(cfg, n_threads) if n_nodes is None
                    else CostModel(n_nodes=n_nodes))
    g = cached_grid(
        alg,
        seeds=range(cfg.seed0, cfg.seed0 + cfg.n_replicas),
        topologies=[topology],
        workloads=[Workload(ncs_max, cs_shared, cfg.n_steps)],
        threads=[n_threads])
    return g.cells[0].result


def lock_sweep(algs, cfg: BenchConfig, *, ncs_max: int = 0, cs_shared=True,
               tag: str = "sweep", on_result=None) -> list:
    """Thread sweep for each algorithm -> schema series list.
    ``on_result(alg, threads, BenchResult)`` lets a caller reuse the full
    per-cell results (e.g. locks-ext's profile table) without re-running
    the cells."""
    series = []
    for alg in algs:
        points = []
        for t in cfg.threads:
            t0 = time.time()
            r = bench_cell(alg, t, cfg, ncs_max=ncs_max, cs_shared=cs_shared)
            wall = time.time() - t0
            if on_result is not None:
                on_result(alg, t, r)
            p = {"threads": t, "episodes": r.episodes,
                 "wall_s": round(wall, 3)}
            for m in POINT_METRICS:
                p[m] = round(float(getattr(r, m)), 4)
            points.append(p)
            if cfg.verbose:
                emit(f"{tag}/{alg}/T{t}",
                     wall / max(r.episodes, 1) * 1e6,
                     f"thr={r.throughput:.3f}/kcyc "
                     f"miss/ep={r.miss_per_episode:.2f}")
        series.append({"label": alg, "points": points})
    return series


def coherence_rows(algs, cfg: BenchConfig, n_threads: int = 10,
                   paper: dict | None = None) -> list:
    """Table 1: coherence traffic per episode, degenerate local CS. The
    1-node and 2-node NUMA variants run in one jit per algorithm."""
    paper = paper or {}
    n_threads = min(n_threads, max(max(cfg.threads), 2))
    rows = []
    for alg in algs:
        t0 = time.time()
        # both NUMA variants are one stacked-topology grid: one jit/alg
        g = cached_grid(
            alg,
            seeds=range(cfg.seed0, cfg.seed0 + cfg.n_replicas),
            topologies=[CostModel(n_nodes=1), CostModel(n_nodes=2)],
            workloads=[Workload(0, False, cfg.n_steps)],
            threads=[n_threads])
        r1 = g.cell(topology="flat:1").result
        r2 = g.cell(topology="flat:2").result
        rows.append({
            "lock": alg,
            "miss_per_episode": round(r1.miss_per_episode, 2),
            "inval_per_episode": round(r1.inval_per_episode, 2),
            "remote_per_episode_numa": round(r2.remote_per_episode, 2),
            "paper_invalidations": paper.get(alg),
        })
        if cfg.verbose:
            emit(f"coherence/{alg}", (time.time() - t0) * 1e6
                 / max(r1.episodes, 1),
                 f"miss/ep={r1.miss_per_episode:.2f} "
                 f"paper={paper.get(alg)}")
    return rows


# --- admission-policy instrumentation (core.admission) ----------------------

def bypass_trace(policy: str, n_threads: int = 8, n_events: int = 2000,
                 seed: int = 0) -> dict:
    """Closed-loop drive of an ``AdmissionQueue``: every thread re-arrives
    immediately after service (sustained contention). For each completed
    wait, record how many admissions of later arrivals overtook it —
    total, and by any *single* other thread (the paper's §2 bound is 1 for
    reciprocating, 0 for FIFO, unbounded for LIFO)."""
    q = POLICIES[policy](seed)
    arrival: dict = {}
    suffered: dict = {}
    by_thread: dict = {}
    seq = 0
    for t in range(n_threads):
        q.push(t)
        arrival[t], suffered[t], by_thread[t] = seq, 0, {}
        seq += 1
    per_wait, per_wait_single = [], []
    for _ in range(n_events):
        s = q.pop()
        if s is None:
            break
        for t, a in arrival.items():
            if t != s and a < arrival[s]:
                suffered[t] += 1
                by_thread[t][s] = by_thread[t].get(s, 0) + 1
        per_wait.append(suffered[s])
        per_wait_single.append(max(by_thread[s].values(), default=0))
        del arrival[s]
        arrival[s], suffered[s], by_thread[s] = seq, 0, {}
        q.push(s)
        seq += 1
    return {
        "per_wait": per_wait,
        "per_wait_single": per_wait_single,
        # threads still waiting at the end (LIFO starvation shows here)
        "max_outstanding": max(suffered.values(), default=0),
    }


def bypass_histograms(policies, n_threads: int = 8, n_events: int = 2000,
                      seed: int = 0, max_bin: int = 8):
    """Histogram the per-wait bypass counts for each admission policy.

    Returns ``(bins, series, stat_rows)`` where bins are
    ``[0, 1, ..., max_bin-1, f"{max_bin}+"]``.
    """
    bins = [str(i) for i in range(max_bin)] + [f"{max_bin}+"]
    series, stat_rows = [], []
    for pol in policies:
        tr = bypass_trace(pol, n_threads=n_threads, n_events=n_events,
                          seed=seed)
        counts = [0] * (max_bin + 1)
        for v in tr["per_wait"]:
            counts[min(v, max_bin)] += 1
        series.append({"label": pol, "counts": counts})
        bound = max_bypass_bound(pol, n_threads)
        stat_rows.append({
            "policy": pol,
            "completed_waits": len(tr["per_wait"]),
            "mean_bypass": round(float(np.mean(tr["per_wait"] or [0])), 3),
            "max_bypass_per_wait": int(max(tr["per_wait"], default=0)),
            "max_bypass_by_single_thread":
                int(max(tr["per_wait_single"], default=0)),
            "max_outstanding_unserved": int(tr["max_outstanding"]),
            "theoretical_single_thread_bound":
                ("inf" if bound == float("inf") else int(bound)),
        })
    return bins, series, stat_rows


# --- reference-interleaver fairness probes (Table 2, §9) --------------------

def reference_fairness(n_threads: int = 5, n_ops: int = 8000) -> dict:
    from repro.core.locks.reference import ALGORITHMS
    from repro.core.sim.interleave import run as ref_run
    r = ref_run(ALGORITHMS["reciprocating"](n_threads), n_threads,
                n_ops=n_ops, policy="rr")
    cyc = r.cycle()
    letters = "ABCDEFGH"[:n_threads]
    return {
        "cycle": list(cyc) if cyc else None,
        "cycle_str": "".join(letters[t] for t in cyc) if cyc else None,
        "cycle_admissions_sorted":
            sorted(cyc.count(t) for t in range(n_threads)) if cyc else None,
        "unfairness": round(r.unfairness(), 3),
    }


def mitigated_unfairness(n_threads: int = 5, n_events: int = 4000,
                         seed: int = 0) -> float:
    """§9.4 randomized intra-segment order: long-run max/min admissions."""
    from repro.core.admission import ReciprocatingQueue
    q = ReciprocatingQueue(seed, mitigate=True)
    counts = np.zeros(n_threads, int)
    for i in range(n_events):
        q.push(i % n_threads)
        got = q.pop()
        if got is not None:
            counts[got] += 1
    return float(counts.max() / max(counts.min(), 1))
