"""Registry-driven benchmark harness (paper §7 evaluation).

Usage::

    PYTHONPATH=src python -m repro.bench list
    PYTHONPATH=src python -m repro.bench run --suite paper \\
        --out BENCH_paper.json          # also renders docs/RESULTS.md

Programmatic::

    from repro.bench import BenchConfig, run_suite
    doc = run_suite("coherence", BenchConfig(quick=True))
"""
from repro.bench.cache import (        # noqa: F401
    ExperimentCache, configure as configure_cache, get_cache,
)
from repro.bench.registry import (     # noqa: F401
    BenchConfig, Suite, get, names, register, run_suite,
)
from repro.bench.schema import (       # noqa: F401
    SCHEMA_VERSION, TREND_SCHEMA_VERSION, load_result, load_trend,
    save_result, validate_result,
)
