"""Command-line interface: ``python -m repro.bench <command>``.

Commands:

* ``list``                      — show the suite catalogue; ``--programs``
                                  enumerates the registered lock specs
                                  (phase anatomy, registers, memory
                                  regions), ``--topologies`` the machine
                                  topology presets, ``--schedulers`` the
                                  hostile-OS scheduler presets,
                                  ``--routers`` the fleet-gateway routing
                                  policies (serve/gateway.py),
                                  ``--cache`` the experiment-cache state
                                  plus each suite's latest trend entry
                                  (wall time / hit rate from
                                  ``BENCH_trend.json``),
                                  ``--suites`` the suites; flags combine
* ``run --suite paper --out BENCH_paper.json``
                                — run a suite, write the schema-valid JSON
                                  result, and (for the ``paper`` suite, or
                                  whenever ``--report`` is given) render
                                  ``docs/RESULTS.md`` from it. Cells are
                                  served from the content-addressed
                                  experiment cache (``bench/cache.py``)
                                  when their inputs are unchanged;
                                  ``--no-cache`` forces regeneration
                                  (the store is still refreshed) and
                                  ``--cache-dir`` moves the store. Every
                                  run appends a harness-performance
                                  entry to ``BENCH_trend.json`` next to
                                  ``--out`` (``--trend`` to relocate,
                                  ``--no-trend`` to skip)
* ``report --in BENCH_paper.json [--out docs/RESULTS.md]``
                                — re-render markdown from an existing result
* ``validate --in BENCH_paper.json``
                                — schema-check a result document
* ``verify [--lock a,b] [--exhaustive]``
                                — run the static analyzer + small-scope
                                  model checker over the lock zoo
                                  (``core/locks/cfg.py`` /
                                  ``core/locks/verify.py``), print the
                                  verified property matrix, splice it
                                  into ``docs/RESULTS.md``, and exit
                                  non-zero (with minimal counterexample
                                  traces) on any violation.
                                  ``--exhaustive`` re-certifies at 3
                                  threads instead of 2
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from repro.bench import cache as cachemod
from repro.bench import registry, report, schema

DEFAULT_REPORT = "docs/RESULTS.md"
DEFAULT_TREND = "BENCH_trend.json"


def _parse_threads(text: str) -> tuple:
    return tuple(int(t) for t in text.split(",") if t)


def _build_config(args) -> registry.BenchConfig:
    kw = {}
    if args.threads:
        threads = _parse_threads(args.threads)
        bad = [t for t in threads if t < 1]
        if bad:
            raise ValueError(f"--threads values must be >= 1, got {bad}")
        kw["threads"] = threads
    if args.steps is not None:
        kw["n_steps"] = args.steps
    if args.replicas is not None:
        kw["n_replicas"] = args.replicas
    if args.algs:
        from repro.core.locks.programs import PROGRAMS
        algs = tuple(args.algs.split(","))
        bad = [a for a in algs if a not in PROGRAMS]
        if bad:
            raise ValueError(f"unknown lock program(s) {bad}; "
                             f"available: {sorted(PROGRAMS)}")
        kw["algs"] = algs
    kw["seed0"] = args.seed
    kw["quick"] = args.quick
    kw["verbose"] = not args.no_progress
    return registry.BenchConfig(**kw)


def _print_cache_status(trend_path: str) -> None:
    store = cachemod.get_cache()
    d = store.describe()
    state = "enabled" if d["enabled"] else "DISABLED"
    print(f"# experiment cache (bench/cache.py, key v"
          f"{cachemod.CACHE_KEY_VERSION})")
    print(f"{'store':12s} {d['root']} — {state}, {d['entries']} entries, "
          f"{d['bytes'] / 1024:.1f} KiB")
    trend = schema.load_trend(trend_path)
    latest: dict = {}
    for e in trend["entries"]:
        latest[e.get("suite")] = e       # last entry per suite wins
    if not latest:
        print(f"{'trend':12s} no {trend_path} yet — populated by "
              "`run` (per-suite wall time / traces / hit rate)")
        return
    print(f"{'trend':12s} latest per suite from {trend_path}:")
    for name in sorted(latest):
        e = latest[name]
        hits, misses = e.get("cache_hits"), e.get("cache_misses")
        rate = e.get("cache_hit_rate")
        cache_txt = ("no cacheable cells" if not (hits or misses) else
                     f"{hits}/{hits + misses} hits "
                     f"({(rate or 0) * 100:.0f}%)")
        quick = " (quick)" if e.get("quick") else ""
        print(f"{'':12s} {name:12s} wall={e.get('wall_s')}s "
              f"traces={e.get('xla_traces')} {cache_txt}{quick}")


def cmd_list(args) -> int:
    show_programs = getattr(args, "programs", False)
    show_topologies = getattr(args, "topologies", False)
    show_schedulers = getattr(args, "schedulers", False)
    show_routers = getattr(args, "routers", False)
    show_backends = getattr(args, "backends", False)
    show_cache = getattr(args, "cache", False)
    show_properties = getattr(args, "properties", False)
    show_suites = (getattr(args, "suites", False)
                   or not (show_programs or show_topologies
                           or show_schedulers or show_routers
                           or show_backends or show_cache
                           or show_properties))
    if show_suites:
        print("# suites")
        for name in registry.names():
            s = registry.get(name)
            print(f"{name:12s} {s.title}")
            print(f"{'':12s}   {s.description}")
    if show_programs:
        from repro.core.locks.programs import (
            NEW_VARIANTS, PROGRAMS, describe_program,
        )
        print("# lock programs (LockSpec phase anatomy — "
              "core/locks/specs.py)")
        for name in sorted(PROGRAMS):
            d = describe_program(name)
            phases = " ".join(
                f"{p}:{len(steps)}" for p, steps in d["phases"].items()
                if steps)
            regions = ", ".join(f"{n}[{sz} {kind}]"
                                for n, sz, kind in d["regions"])
            mem = ", ".join(list(d["words"]) + ([regions] if regions else []))
            tag = "  (new variant)" if name in NEW_VARIANTS else ""
            print(f"{name:15s} {phases}{tag}")
            print(f"{'':15s}   regs: {', '.join(d['regs']) or '-'}; "
                  f"mem: {mem}")
    if show_topologies:
        from repro.core.sim.topology import catalogue
        print("# machine topologies (core/sim/topology.py; outermost "
              "tier first, @cost = transfer cycles, * = NUMA-remote)")
        for name, summary in catalogue():
            print(f"{name:12s} {summary}")
        print(f"{'':12s} pass presets/shorthand to SimEngine(topology=...) "
              "or bench_lock(cost=...)")
    if show_schedulers:
        from repro.core.sim.sched import catalogue
        print("# hostile-OS schedulers (core/sim/sched.py; quanta in "
              "simulator cycles, dedicated = never preempted)")
        for name, summary in catalogue():
            print(f"{name:12s} {summary}")
        print(f"{'':12s} pass presets/shorthand to "
              "SimEngine(scheduler=...) or .grid(schedulers=[...])")
    if show_routers:
        from repro.serve.gateway import catalogue
        print("# fleet gateway routers (serve/gateway.py; targets are "
              "always slack-bearing replicas — SERVING.md §8)")
        for name, summary in catalogue():
            print(f"{name:14s} {summary}")
        print(f"{'':14s} pass names to FleetGateway(router=...) or the "
              "gateway bench suite")
    if show_backends:
        from repro.core.locks.pallas_backend import backends
        print("# execution backends (availability-probed; "
              "core/locks/pallas_backend.py)")
        for row in backends():
            mark = "available" if row["available"] else "UNAVAILABLE"
            print(f"{row['name']:17s} {mark:12s} {row['detail']}")
        print(f"{'':17s} the `measured` suite auto-selects "
              "pallas-device when present, else pallas-interpret")
    if show_properties:
        from repro.core.locks import verify as verify_mod
        print("# verified/declared lock properties (structural analysis "
              "— core/locks/cfg.py; `verify` adds the model check)")
        verdicts = verify_mod.verify_all(model=False)
        print(verify_mod.render_matrix(verdicts))
    if show_cache:
        _print_cache_status(getattr(args, "trend", None) or DEFAULT_TREND)
    return 0


def cmd_verify(args) -> int:
    from repro.core.locks import verify as verify_mod
    names = tuple(n for n in (args.lock or "").split(",") if n)
    t0 = time.time()

    def progress(v):
        if not args.no_progress:
            state = "ok" if v.ok else "FAIL"
            cert = v.check.certificate if v.check else "structural only"
            print(f"# {v.name:26s} {state}  {cert}", flush=True)

    try:
        verdicts = verify_mod.verify_all(
            names=names, exhaustive=args.exhaustive,
            episodes=args.episodes, max_states=args.max_states,
            on_result=progress)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    print()
    print(verify_mod.render_matrix(verdicts))
    bad = [v for v in verdicts if not v.ok]
    for v in bad:
        print(f"\n# {v.name}: VERIFICATION FAILED")
        if v.error:
            print(f"  compile/spec error: {v.error}")
        for viol in v.structural_violations:
            print(f"  structural: {viol}")
        if v.check is not None and not v.check.ok:
            print(f"  model check ({v.check.violation}): {v.check.detail}")
            print(f"  minimal counterexample "
                  f"({len(v.check.trace)} transitions):")
            for line in v.check.trace:
                print(f"    {line}")
    scope = "T=3" if args.exhaustive else "T=2"
    print(f"\n# {len(verdicts) - len(bad)}/{len(verdicts)} locks certified "
          f"({scope}, {time.time() - t0:.1f}s)")
    if not args.no_results and not names:
        from repro.bench import report as reportmod
        note = ("Generated by `python -m repro.bench verify"
                + (" --exhaustive" if args.exhaustive else "") + "`.")
        reportmod.splice_section(
            args.results, reportmod.VERIFY_HEADER,
            reportmod.verify_section_lines(verdicts, note))
        print(f"# spliced matrix into {args.results}")
    return 1 if bad else 0


def cmd_run(args) -> int:
    cfg = _build_config(args)
    cachemod.configure(root=args.cache_dir or None,
                       read=not args.no_cache)
    t0 = time.time()
    if cfg.verbose:
        print("name,us_per_call,derived")
        print(f"# === suite {args.suite} ===", flush=True)
    doc = registry.run_suite(args.suite, cfg)
    schema.save_result(doc, args.out)
    print(f"# wrote {args.out} ({len(doc['experiments'])} experiments, "
          f"{time.time() - t0:.1f}s)")
    if not args.no_trend:
        trend_path = args.trend or os.path.join(
            os.path.dirname(args.out) or ".", DEFAULT_TREND)
        schema.append_trend(trend_path, schema.trend_entry(doc))
        h = doc["harness"]
        print(f"# trend -> {trend_path} (wall={h['wall_s']}s "
              f"traces={h['xla_traces']} cache {h['cache_hits']} hit / "
              f"{h['cache_misses']} miss)")
    report_path = args.report
    if report_path is None and args.suite == "paper" and not args.no_report:
        report_path = DEFAULT_REPORT
    if report_path:
        report.write_report(doc, report_path)
        print(f"# rendered {report_path}")
    return 0


def cmd_report(args) -> int:
    doc = schema.load_result(args.infile)
    out = args.out or DEFAULT_REPORT
    report.write_report(doc, out)
    print(f"# rendered {out} from {args.infile}")
    return 0


def cmd_validate(args) -> int:
    import json
    with open(args.infile) as f:
        doc = json.load(f)
    errors = schema.validate_result(doc)
    if errors:
        print(f"{args.infile}: INVALID")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"{args.infile}: valid {schema.SCHEMA_VERSION} "
          f"(suite={doc['suite']}, {len(doc['experiments'])} experiments)")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="Registry-driven benchmark harness (paper Figs 1-3, "
                    "Table 1, fairness; see `list`).")
    sub = ap.add_subparsers(dest="command", required=True)

    ls = sub.add_parser("list",
                        help="show the suite / lock-program catalogue")
    ls.add_argument("--suites", action="store_true",
                    help="enumerate registered suites (the default)")
    ls.add_argument("--programs", action="store_true",
                    help="enumerate registered lock specs with their "
                         "phase anatomy")
    ls.add_argument("--topologies", action="store_true",
                    help="enumerate the machine-topology preset "
                         "catalogue (core/sim/topology.py)")
    ls.add_argument("--schedulers", action="store_true",
                    help="enumerate the hostile-OS scheduler preset "
                         "catalogue (core/sim/sched.py)")
    ls.add_argument("--routers", action="store_true",
                    help="enumerate the fleet-gateway routing policy "
                         "catalogue (serve/gateway.py)")
    ls.add_argument("--backends", action="store_true",
                    help="probe and enumerate the execution backends "
                         "(sim / pallas-interpret / pallas-device — "
                         "core/locks/pallas_backend.py)")
    ls.add_argument("--properties", action="store_true",
                    help="print the per-lock verified/declared property "
                         "matrix (structural analysis only; see `verify`)")
    ls.add_argument("--cache", action="store_true",
                    help="show experiment-cache state and each suite's "
                         "latest trend entry (BENCH_trend.json)")
    ls.add_argument("--trend", default=None,
                    help=f"trend log to read for --cache "
                         f"(default: {DEFAULT_TREND})")
    ls.set_defaults(fn=cmd_list)

    run = sub.add_parser("run", help="run a suite and write its JSON result")
    run.add_argument("--suite", required=True)
    run.add_argument("--out", required=True,
                     help="output JSON path (e.g. BENCH_paper.json)")
    run.add_argument("--report", default=None,
                     help="also render markdown to this path "
                          f"(default for --suite paper: {DEFAULT_REPORT})")
    run.add_argument("--no-report", action="store_true",
                     help="skip the default markdown render")
    run.add_argument("--quick", action="store_true",
                     help="tiny grid for smoke runs")
    run.add_argument("--threads", default="",
                     help="comma-separated thread counts, e.g. 1,2,4,8")
    run.add_argument("--steps", type=int, default=None,
                     help="micro-steps per cell")
    run.add_argument("--replicas", type=int, default=None,
                     help="vmapped replica ensemble size per cell")
    run.add_argument("--algs", default="",
                     help="comma-separated lock subset (default: suite's)")
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--no-progress", action="store_true")
    run.add_argument("--no-cache", action="store_true",
                     help="force regeneration: skip cache lookups "
                          "(results are still stored for later runs)")
    run.add_argument("--cache-dir", default="",
                     help="experiment-cache directory (default: "
                          f"{cachemod.DEFAULT_ROOT} or "
                          "$REPRO_BENCH_CACHE_DIR)")
    run.add_argument("--trend", default=None,
                     help="harness-performance trend log path (default: "
                          f"{DEFAULT_TREND} next to --out)")
    run.add_argument("--no-trend", action="store_true",
                     help="skip the trend-log append")
    run.set_defaults(fn=cmd_run)

    rep = sub.add_parser("report",
                         help="re-render markdown from an existing result")
    rep.add_argument("--in", dest="infile", required=True)
    rep.add_argument("--out", default=None)
    rep.set_defaults(fn=cmd_report)

    val = sub.add_parser("validate", help="schema-check a result document")
    val.add_argument("--in", dest="infile", required=True)
    val.set_defaults(fn=cmd_validate)

    ver = sub.add_parser(
        "verify",
        help="statically verify the lock zoo and model-check all "
             "interleavings at small scope")
    ver.add_argument("--lock", default="",
                     help="comma-separated lock subset (default: all; "
                          "subsets skip the RESULTS.md splice)")
    ver.add_argument("--exhaustive", action="store_true",
                     help="model-check at 3 threads (default certifies "
                          "at 2)")
    ver.add_argument("--episodes", type=int, default=2,
                     help="lock episodes per thread in the model check")
    ver.add_argument("--max-states", type=int, default=200_000,
                     help="state-expansion budget per lock (exceeding it "
                          "downgrades the certificate to 'bounded')")
    ver.add_argument("--results", default=DEFAULT_REPORT,
                     help="markdown file to splice the property matrix "
                          f"into (default: {DEFAULT_REPORT})")
    ver.add_argument("--no-results", action="store_true",
                     help="skip the RESULTS.md splice")
    ver.add_argument("--no-progress", action="store_true")
    ver.set_defaults(fn=cmd_verify)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except registry.UnknownSuiteError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
    except FileNotFoundError as e:
        print(f"error: no such file: {e.filename}", file=sys.stderr)
    except ValueError as e:           # invalid result document
        print(f"error: {e}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
