"""The ``measured`` bench tier: paper sweeps on the Pallas backend.

Runs the Fig. 1-3 style throughput/latency sweeps with *wall-clock*
time instead of model cycles: every cell is one
``core/locks/pallas_backend.run_measured`` launch — the same ``LockIR``
the sim executes, lowered to a kernel that hammers the lock words
through the device atomics layer. On CI (no accelerator) the cells run
in Pallas interpret mode: schedule-exact, linearizable, slow — so the
wall numbers are an interpreter proxy while the *structure* (admission
order, episode split, mutual exclusion) is the real thing, and the
backend-agreement table cross-checks it against the sim at uniform
cost.

Cells are fronted by the experiment cache under a dedicated
``"measured"`` key kind (``_measured_key``): the key starts from the
same program fingerprint as sim cells but never collides with the sim
``"cell"`` keyspace, and bakes in the backend mode so interpret and
device runs cache separately. Cache hit/miss accounting flows through
``store.stats`` like every other cell, so suite-level telemetry
(``BENCH_trend.json`` wall/traces/hit-rate) covers measured runs with
no extra plumbing.

The calibration experiment (``bench/calibrate.py``) closes the
sim->silicon loop: it fits the sim's ``CostModel`` scale to the
measured curves and reports the per-cell fitted-vs-measured error
table that lands in docs/RESULTS.md.
"""
from __future__ import annotations

import hashlib
import json
import time

import numpy as np

from repro.bench import cache as cachemod
from repro.bench import calibrate, sweep
from repro.bench.registry import BenchConfig, emit
from repro.bench.schema import (
    scalars_experiment, sweep_experiment, table_experiment,
)
from repro.core.sim.machine import CostModel

#: the measured lock subset: the paper trio + a DSL-authored pair, kept
#: small because every cell is a real kernel launch (and, in interpret
#: mode, a slice-by-slice emulation)
MEASURED_ALGS = ("reciprocating", "ticket", "mcs", "ttas", "hapax")
#: locks whose round-robin admission order must agree between backends
AGREEMENT_ALGS = ("reciprocating", "mcs", "ticket", "hapax")


def _algs(cfg: BenchConfig) -> tuple:
    return tuple(cfg.algs) if cfg.algs else MEASURED_ALGS


def _rounds(cfg: BenchConfig, n_threads: int) -> int:
    # one sim step is one micro-op slice; a measured round is T slices —
    # match the per-cell op budget so the tiers are comparable
    return max(cfg.n_steps // max(n_threads, 1), 64)


def _measured_key(ir, n_threads: int, rounds: int, seed: int,
                  interpret: bool) -> str:
    """Content key of a measured cell. Distinct key *kind* from the sim
    ``"cell"`` keyspace (bench/cache.py) — a measured run and a sim run
    of the same program can never collide."""
    fp = cachemod.program_fingerprint(ir)     # duck-types on the IR
    return hashlib.sha256(json.dumps(
        {"v": cachemod.CACHE_KEY_VERSION, "kind": "measured", "fp": fp,
         "T": int(n_threads), "rounds": int(rounds), "seed": int(seed),
         "ncs": int(ir.ncs_max), "cs": ir.cs_mode,
         "backend": "interpret" if interpret else "device"},
        sort_keys=True).encode()).hexdigest()


def measured_cell(alg: str, n_threads: int, rounds: int, *,
                  ncs_max: int = 0, cs_shared=True, seed: int = 0,
                  interpret: bool | None = None) -> dict:
    """One measured cell, cache-fronted. Returns the summary dict (not
    the ``MeasuredResult`` — the cache stores plain JSON)."""
    import jax

    from repro.core.locks.pallas_backend import resolve_ir, run_measured

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    ir = resolve_ir(alg, n_threads, ncs_max=ncs_max, cs_shared=cs_shared)
    store = cachemod.get_cache()
    key = _measured_key(ir, n_threads, rounds, seed, interpret)
    s = store.get(key)
    if s is not None:
        if store.enabled:
            store.stats.hits += 1
        return s
    if store.enabled:
        store.stats.misses += 1
    r = run_measured(ir, n_threads, rounds, seed=seed, interpret=interpret)
    s = {
        "lock": r.name, "threads": n_threads, "rounds": rounds,
        "backend": r.backend, "episodes": r.episodes,
        "per_thread": r.per_thread.tolist(),
        "collisions": r.collisions, "returns": r.returns,
        "aborts": r.aborts, "admission_counts": r.admission_counts,
        "admissions": r.admissions[:64].tolist(),
        "wall_s": round(r.wall_s, 6), "compile_s": round(r.compile_s, 3),
        "throughput_eps": round(r.throughput_eps, 1),
        "episodes_per_kslice": round(r.episodes_per_kslice, 4),
        "latency_slices": round(r.latency_slices, 3),
    }
    if store.enabled:
        store.put(key, s)
    return s


def measured_sweep(algs, cfg: BenchConfig, *, ncs_max: int = 0,
                   cs_shared=True, tag: str = "measured",
                   on_cell=None) -> list:
    """Thread sweep on the measured backend -> schema series list."""
    series = []
    for alg in algs:
        points = []
        for t in cfg.threads:
            t0 = time.time()
            c = measured_cell(alg, t, _rounds(cfg, t), ncs_max=ncs_max,
                              cs_shared=cs_shared, seed=cfg.seed0)
            wall = time.time() - t0
            if on_cell is not None:
                on_cell(alg, t, c)
            points.append({
                "threads": t, "episodes": c["episodes"],
                "throughput_eps": c["throughput_eps"],
                "episodes_per_kslice": c["episodes_per_kslice"],
                "latency_slices": c["latency_slices"],
                "collisions": c["collisions"],
                "wall_s": round(wall, 3),
            })
            if cfg.verbose:
                emit(f"{tag}/{alg}/T{t}",
                     wall / max(c["episodes"], 1) * 1e6,
                     f"eps/ks={c['episodes_per_kslice']:.2f} "
                     f"coll={c['collisions']} [{c['backend']}]")
        series.append({"label": alg, "points": points})
    return series


# --- backend-agreement differential ------------------------------------------

def agreement_rows(cfg: BenchConfig, algs=AGREEMENT_ALGS,
                   n_threads: int = 3) -> list:
    """The backend-agreement harness: the sim under a *uniform* cost
    model (hit == miss == 1 cycle) dispatches exactly the measured
    kernel's round-robin op schedule, so both backends must produce the
    same admission order and, over the compared admission prefix, the
    same per-thread CS counts. A mismatch means one backend's machine
    semantics drifted."""
    from repro.core.locks.programs import PROGRAMS
    from repro.core.sim.machine import run_machine

    uni = CostModel(hit=1, local_miss=1, remote_miss=1)
    sim_steps = 1_000 if cfg.quick else 3_000
    rounds = 150 if cfg.quick else 400
    rows = []
    for alg in algs:
        prog = PROGRAMS[alg](n_threads, ncs_max=0, cs_shared=True)
        s = run_machine(prog, n_threads, sim_steps, cm=uni, seed=cfg.seed0)
        sim_order = np.asarray(s.adm_log)[:int(s.adm_cnt)].tolist()
        c = measured_cell(alg, n_threads, rounds, seed=cfg.seed0)
        pal_order = c["admissions"][:c["admission_counts"]]
        n = min(len(sim_order), len(pal_order), 48)
        match = sim_order[:n] == pal_order[:n]
        sim_cnt = np.bincount(sim_order[:n], minlength=n_threads)
        pal_cnt = np.bincount(pal_order[:n], minlength=n_threads)
        rows.append({
            "lock": alg, "threads": n_threads, "compared": n,
            "order_match": bool(match),
            "cs_counts_match": bool((sim_cnt == pal_cnt).all()),
            "cs_split": "/".join(str(int(x)) for x in pal_cnt),
            "collisions": c["collisions"],
        })
        if cfg.verbose:
            emit(f"measured_agree/{alg}", 0.0,
                 f"order_match={match} n={n} coll={c['collisions']}")
    return rows


# --- suite builder ------------------------------------------------------------

def build_measured(cfg: BenchConfig) -> list:
    """The ``measured`` suite: backend catalogue, Fig 1-3 style sweeps on
    the Pallas backend, the backend-agreement table, and the
    CostModel-calibration error table (bench/calibrate.py)."""
    from repro.core.locks.pallas_backend import backends

    exps = [table_experiment(
        "measured_backends", "Execution backends (availability-probed)",
        ("name", "available", "detail"),
        [dict(r) for r in backends()],
        meta={"note": "`repro.bench list --backends` prints this "
                      "catalogue; measured cells auto-select "
                      "pallas-device when an accelerator is present."})]

    algs = _algs(cfg)
    meas: dict = {}
    a = measured_sweep(algs, cfg, ncs_max=0, tag="measured_max_contention",
                       on_cell=lambda al, t, c: meas.__setitem__((al, t), c))
    exps.append(sweep_experiment(
        "measured_fig1a", "Measured Fig. 1a analogue — throughput vs "
        "threads, maximal contention (Pallas backend)", "threads", a))
    if not cfg.quick:
        b = measured_sweep(algs, cfg, ncs_max=250,
                           tag="measured_random_ncs")
        exps.append(sweep_experiment(
            "measured_fig1b", "Measured Fig. 1b analogue — random NCS "
            "delay (Pallas backend)", "threads", b))
        k = measured_sweep(algs, cfg, ncs_max=60, cs_shared="ro",
                           tag="measured_kvstore")
        exps.append(sweep_experiment(
            "measured_fig3", "Measured Fig. 3 analogue — read-only CS, "
            "random key-gen NCS (Pallas backend)", "threads", k))

    rows = agreement_rows(cfg)
    exps.append(table_experiment(
        "measured_agreement", "Backend agreement — sim (uniform cost) vs "
        "Pallas round-robin schedule", ("lock", "threads", "compared",
        "order_match", "cs_counts_match", "cs_split", "collisions"), rows,
        meta={"note": "order_match compares admission-order prefixes; "
                      "collisions counts mutual-exclusion violations "
                      "observed by the in-kernel guard (must be 0)."}))

    fit = calibrate.calibrate(meas, cfg)
    exps.append(table_experiment(
        "measured_calibration", "CostModel calibration — fitted sim "
        "throughput vs measured (per cell)",
        ("lock", "threads", "measured_eps_per_kslice", "sim_eps_per_kcycle",
         "fitted", "rel_err"),
        fit.rows,
        meta={"note": "fit: measured ~= scale * sim(cost model); "
                      "see bench/calibrate.py for the model."}))
    exps.append(scalars_experiment(
        "measured_calibration_fit", "CostModel calibration fit",
        {"scale_kslice_per_kcycle": fit.scale,
         "mean_rel_err": fit.mean_rel_err,
         "max_rel_err": fit.max_rel_err,
         "cost_model": fit.cost_label,
         "candidates_tried": fit.candidates_tried}))
    return exps
