"""Suite registry for the benchmark harness.

A *suite* is a named, registered builder that turns a ``BenchConfig`` into
a list of schema experiments (see ``repro.bench.schema``). Suites compose:
the ``paper`` suite reuses the same builders the per-figure suites
register, so ``run --suite paper`` and ``run --suite coherence`` cannot
drift apart.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, replace
from typing import Callable

from repro.bench import schema


@dataclass(frozen=True)
class BenchConfig:
    """Knobs shared by every suite; ``quick`` shrinks the grid for smoke
    runs (CI / pytest) without changing any code path."""
    threads: tuple = (1, 2, 4, 8, 16, 24)
    n_steps: int = 12_000
    n_replicas: int = 2
    numa_above: int = 8       # thread counts above this use 2 NUMA nodes
    seed0: int = 0
    quick: bool = False
    algs: tuple = ()          # () => suite default (usually all programs)
    verbose: bool = True

    def resolved(self) -> "BenchConfig":
        """Apply ``quick`` shrinkage — but only to knobs still at their
        class defaults, so explicit --threads/--steps/--replicas win."""
        if not self.quick:
            return self
        d = BenchConfig()
        return replace(
            self,
            threads=(1, 2, 4) if self.threads == d.threads else self.threads,
            n_steps=1_500 if self.n_steps == d.n_steps else self.n_steps,
            n_replicas=(1 if self.n_replicas == d.n_replicas
                        else self.n_replicas))

    def to_json(self) -> dict:
        d = asdict(self)
        d["threads"] = list(self.threads)
        d["algs"] = list(self.algs)
        return d


@dataclass(frozen=True)
class Suite:
    name: str
    title: str
    description: str
    build: Callable          # (BenchConfig) -> list[experiment dict]
    tags: tuple = ()


_REGISTRY: dict = {}


def register(name: str, title: str, description: str, tags: tuple = ()):
    """Decorator: register ``fn(cfg) -> [experiment, ...]`` as a suite."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"suite {name!r} already registered")
        _REGISTRY[name] = Suite(name=name, title=title,
                                description=description, build=fn, tags=tags)
        return fn
    return deco


class UnknownSuiteError(KeyError):
    pass


def get(name: str) -> Suite:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownSuiteError(
            f"unknown suite {name!r}; available: {names()}") from None


def names() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # Built-in suites live in repro.bench.suites; importing it populates
    # the registry exactly once (idempotent thanks to sys.modules).
    from repro.bench import suites  # noqa: F401


def run_suite(name: str, cfg: BenchConfig | None = None) -> dict:
    """Build a suite into a schema-valid result document.

    The document carries a ``"harness"`` block — wall time, fresh XLA
    traces (the process-wide ``engine.trace_count()`` delta, so traces
    paid by throwaway engines are counted too), and experiment-cache
    hit/miss/store deltas — which ``BENCH_trend.json`` aggregates
    across runs (see ``schema.trend_entry``).
    """
    import time

    from repro.bench import cache as cachemod
    from repro.core.sim import engine as enginemod

    suite = get(name)
    cfg = (cfg or BenchConfig()).resolved()
    t0 = time.time()
    traces0 = enginemod.trace_count()
    store = cachemod.get_cache()
    stats0 = store.stats.snapshot()
    doc = schema.new_result(suite.name, config=cfg.to_json())
    doc["experiments"] = suite.build(cfg)
    stats = store.stats.snapshot()
    hits = stats["hits"] - stats0["hits"]
    misses = stats["misses"] - stats0["misses"]
    doc["harness"] = {
        "wall_s": round(time.time() - t0, 3),
        "xla_traces": enginemod.trace_count() - traces0,
        "cache_enabled": store.enabled,
        "cache_read": store.read,
        "cache_hits": hits,
        "cache_misses": misses,
        "cache_stores": stats["stores"] - stats0["stores"],
        "cache_hit_rate": (round(hits / (hits + misses), 4)
                           if hits + misses else None),
    }
    errors = schema.validate_result(doc)
    if errors:
        raise RuntimeError(f"suite {name!r} produced an invalid document:"
                           "\n  " + "\n  ".join(errors))
    return doc


def emit(name: str, us_per_call: float, derived: str) -> None:
    """Progress line in the historical ``name,us_per_call,derived`` CSV
    format shared with the legacy ``benchmarks/run.py`` driver."""
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)
