"""Built-in benchmark suites.

Each suite maps to a paper artifact (``PYTHONPATH=src python -m repro.bench
list`` shows the catalogue); the ``paper`` suite composes the figure/table
builders end-to-end and is what regenerates ``docs/RESULTS.md``:

  mutexbench   Fig. 1a/1b  thread sweep, maximal contention + random NCS
  atomics      Fig. 2      lock-striped ``std::atomic<struct>`` (rw CS)
  kvstore      Fig. 3      LevelDB-readrandom analogue (read-only CS)
  coherence    Table 1     invalidations / misses per episode
  locks-ext    beyond-paper extended lock zoo: DSL-authored variants
               (hapax / fissile / spin_then_park, core/locks/specs.py)
               vs the paper baselines, plus the park-cost sensitivity
               of spin_then_park
  topology     §3/§8 machine-model sweep: every lock on SMP vs 2/4-node
               NUMA vs clustered-CCX (core/sim/topology.py presets),
               remote-miss scaling vs node count, contiguous vs
               interleaved placement — all through SimEngine.grid
               (one jit per grid shape)
  hostile      beyond-paper hostile-OS sweep (core/sim/sched.py):
               locks × quantum × oversubscription, lock-holder-
               preemption stress, and the abort-rate ladder for the
               timed-wait locks — schedulers ride the grid as stacked
               data (one jit per grid shape)
  fairness     Table 2/§9  palindromic cycle, 2x bound, §9.4 mitigation,
                           bounded-bypass histograms (core.admission)
  residency    App. C      Jensen/decay residual-residency model
  scheduler    beyond-paper reciprocating continuous-batching admission
  serve        beyond-paper serving engine: policy × load sweep on the
               unified core + paged-KV pool, model-backed engine smoke
               (docs/SERVING.md)
  measured     beyond-sim measured tier (DESIGN.md §L2): the Fig 1-3
               sweeps as real Pallas kernels over the device atomics
               layer (bench/measured.py; interpret-mode on CPU), the
               sim-vs-Pallas backend-agreement table, and the CostModel
               calibration error table (bench/calibrate.py)
  kernels      beyond-paper serpentine DMA savings accounting
  roofline     EXPERIMENTS  dry-run artifact aggregation
  paper        Figs 1-3 + Table 1 + topology + fairness/bypass + serve
               + measured, one document
"""
from __future__ import annotations

import glob
import json
import os
import time
from dataclasses import replace

import numpy as np

from repro.bench import report, sweep
from repro.bench.measured import build_measured
from repro.bench.registry import BenchConfig, emit, register
from repro.bench.schema import (
    hist_experiment, scalars_experiment, sweep_experiment, table_experiment,
)
from repro.core.sim import topology as topo
from repro.core.sim.engine import Workload
from repro.core.sim.machine import CostModel

# Lock subsets mirroring what each paper figure actually plots.
FIG1_ALGS = sweep.ALL_ALGS                      # every registered program
FIG2_ALGS = ("reciprocating", "ticket", "mcs", "clh", "hemlock", "ttas")
FIG3_ALGS = ("reciprocating", "ticket", "mcs", "clh", "hemlock")
# Paper Table 1 invalidation counts (T=10): the comparison column.
TABLE1_PAPER = {"reciprocating": 4, "clh": 5, "mcs": 6, "hemlock": 5,
                "ticket": 10, "anderson": None, "ttas": None,
                "retrograde": None}
ADMISSION_POLICIES = ("fifo", "lifo", "reciprocating",
                      "reciprocating_mitigated")


def _algs(cfg: BenchConfig, default) -> tuple:
    return tuple(cfg.algs) if cfg.algs else tuple(default)


# --- figure/table builders (shared by per-figure suites and `paper`) --------

def build_fig1(cfg: BenchConfig, on_result=None) -> list:
    """``on_result`` captures the max-contention BenchResults so composed
    suites (``paper`` -> locks-ext) can reuse the cells instead of
    re-simulating them."""
    a = sweep.lock_sweep(_algs(cfg, FIG1_ALGS), cfg, ncs_max=0,
                         tag="mutexbench_max_contention",
                         on_result=on_result)
    b = sweep.lock_sweep(_algs(cfg, FIG1_ALGS), cfg, ncs_max=250,
                         tag="mutexbench_random_ncs")
    return [
        sweep_experiment(
            "fig1a_max_contention",
            "Figure 1a — MutexBench throughput, maximal contention "
            "(empty NCS)", "threads", a),
        sweep_experiment(
            "fig1b_random_ncs",
            "Figure 1b — MutexBench throughput, random NCS delay",
            "threads", b),
    ]


def build_fig2(cfg: BenchConfig) -> list:
    s = sweep.lock_sweep(_algs(cfg, FIG2_ALGS), cfg, cs_shared="rw",
                         tag="atomics_xchg")
    return [sweep_experiment(
        "fig2_atomics",
        "Figure 2 — lock-striped std::atomic<struct> exchange "
        "(shared-rw CS, empty NCS)", "threads", s)]


def build_fig3(cfg: BenchConfig) -> list:
    s = sweep.lock_sweep(_algs(cfg, FIG3_ALGS), cfg, ncs_max=60,
                         cs_shared="ro", tag="kvstore")
    return [sweep_experiment(
        "fig3_kvstore",
        "Figure 3 — LevelDB-readrandom analogue (read-only CS, "
        "random key-gen NCS)", "threads", s)]


def build_table1(cfg: BenchConfig) -> list:
    rows = sweep.coherence_rows(_algs(cfg, tuple(TABLE1_PAPER)), cfg,
                                n_threads=10, paper=TABLE1_PAPER)
    return [table_experiment(
        "table1_coherence",
        "Table 1 — coherence traffic per contended episode "
        "(T=10, degenerate local CS)",
        ["lock", "miss_per_episode", "inval_per_episode",
         "remote_per_episode_numa", "paper_invalidations"], rows)]


LOCKS_EXT_BASELINES = ("reciprocating", "mcs", "ticket")
# (park_cost, unpark_cost) grid for the spin_then_park sensitivity table
PARK_COSTS = ((0, 0), (10, 30), (25, 75), (50, 150), (100, 300))


def build_locks_ext(cfg: BenchConfig, reuse_series: list | None = None,
                    reuse_cells: dict | None = None) -> list:
    """Extended lock zoo (DESIGN.md §L2): the three DSL-authored variants
    against the reference trio, a phase/coherence profile table at the
    largest thread count, and the spin_then_park park-cost sensitivity.

    ``reuse_series`` / ``reuse_cells`` let the ``paper`` suite hand over
    its already-run Fig. 1a series and per-cell BenchResults (same
    ncs/CS/seed settings) so composed runs re-simulate nothing."""
    from repro.core.locks.programs import NEW_VARIANTS, describe_program

    algs = _algs(cfg, LOCKS_EXT_BASELINES + NEW_VARIANTS)
    t_hi = max(cfg.threads)
    cells: dict = dict(reuse_cells or {})
    reused = {s["label"]: s for s in reuse_series or []}
    series = ([reused[a] for a in algs]
              if all(a in reused for a in algs)
              else sweep.lock_sweep(
                  algs, cfg, ncs_max=0, tag="locksext",
                  on_result=lambda a, t, r: cells.__setitem__((a, t), r)))

    prof_rows = []
    for alg in algs:
        r = cells.get((alg, t_hi))
        cell_us = 0.0                       # reused cell: no new simulation
        if r is None:
            t0 = time.time()
            r = sweep.bench_cell(alg, t_hi, cfg)
            cell_us = (time.time() - t0) * 1e6 / max(r.episodes, 1)
        d = describe_program(alg)
        phases = d["phases"]
        prof_rows.append({
            "lock": alg,
            "spec_steps": "/".join(
                f"{p[0].upper()}{len(phases[p])}"
                for p in ("doorway", "waiting", "entry", "release")),
            "throughput": round(r.throughput, 4),
            "miss_per_episode": round(r.miss_per_episode, 2),
            "latency": round(r.latency, 1),
            "unfairness": round(r.unfairness, 3),
            "bypass_bound": r.bypass_bound,
        })
        if cfg.verbose:
            emit(f"locksext/{alg}", cell_us,
                 f"thr={r.throughput:.3f}/kcyc bypass<={r.bypass_bound}")

    park_rows = []
    costs = PARK_COSTS[1:4] if cfg.quick else PARK_COSTS
    base = sweep.default_machine(cfg, t_hi)
    # the whole park-cost axis is one stacked-topology grid (one jit):
    # dataclasses.replace keeps every other CostModel field intact
    g = sweep.cached_grid(
        "spin_then_park",
        seeds=range(cfg.seed0, cfg.seed0 + cfg.n_replicas),
        topologies=[replace(base, park_cost=p, unpark_cost=u)
                    for p, u in costs],
        workloads=[Workload(0, True, cfg.n_steps)], threads=[t_hi])
    for (park, unpark), cell in zip(costs, g.cells):
        r = cell.result
        park_rows.append({
            "park_cost": park, "unpark_cost": unpark,
            "throughput": round(r.throughput, 4),
            "latency": round(r.latency, 1),
            "miss_per_episode": round(r.miss_per_episode, 2),
        })
    if cfg.verbose:
        lo, hi = park_rows[0]["throughput"], park_rows[-1]["throughput"]
        emit("locksext/park_sensitivity", 0.0,
             f"thr {lo:.3f}->{hi:.3f}/kcyc over {len(park_rows)} park costs")

    return [
        sweep_experiment(
            "locksext_sweep",
            "Extended lock zoo — DSL-authored variants (hapax, fissile, "
            "spin_then_park) vs paper baselines, maximal contention",
            "threads", series),
        table_experiment(
            "locksext_profile",
            f"Extended lock zoo — phase anatomy and coherence profile at "
            f"T={t_hi} (spec_steps = steps per "
            "Doorway/Waiting/Entry/Release phase)",
            ["lock", "spec_steps", "throughput", "miss_per_episode",
             "latency", "unfairness", "bypass_bound"], prof_rows),
        table_experiment(
            "locksext_park",
            f"spin_then_park — throughput/latency vs park+unpark cost "
            f"(T={t_hi}, CostModel hooks in core/sim/machine.py)",
            ["park_cost", "unpark_cost", "throughput", "latency",
             "miss_per_episode"], park_rows),
    ]


# Locks whose remote-miss scaling the paper contrasts (§3, Table 1).
TOPOLOGY_FOCUS = ("reciprocating", "mcs", "ticket")
TOPOLOGY_NODE_COUNTS = (1, 2, 4, 8)


def topology_machines(n_threads: int) -> list:
    """The suite's machine roster, sized so ``n_threads`` always fits:
    degenerate SMP, 2- and 4-node NUMA, and a clustered-CCX part."""
    per2 = max((n_threads + 1) // 2, 1)
    per4 = max((n_threads + 3) // 4, 1)
    return [topo.smp(n_threads), topo.numa(2, per2), topo.numa(4, per4),
            topo.ccx(sockets=2, ccx_per_socket=2, per_ccx=per4)]


def build_topology(cfg: BenchConfig) -> list:
    """Topology suite (DESIGN.md §L1): every lock across the machine
    roster, remote-miss scaling vs NUMA node count, and contiguous vs
    interleaved placement — each lock's whole machine grid is ONE
    ``SimEngine.grid`` call (seed x topology stacked into a single jit),
    and the compile accounting is exported so batching regressions are
    visible in the results document."""
    algs = _algs(cfg, sweep.ALL_ALGS)
    t_hi = min(16, max(max(cfg.threads), 4))
    seeds = range(cfg.seed0, cfg.seed0 + cfg.n_replicas)
    wl = Workload(0, False, cfg.n_steps, label="local_cs")
    machines = topology_machines(t_hi)
    machines.append(machines[1].interleave())     # numa2 + scatter pinning

    grid_rows, compiles, grids, points = [], 0, 0, 0
    for alg in algs:
        t0 = time.time()
        g = sweep.cached_grid(alg, seeds=seeds, topologies=machines,
                              workloads=[wl], threads=[t_hi])
        compiles += g.compiles
        grids += 1
        points += len(machines) * cfg.n_replicas
        for c in g.cells:
            grid_rows.append({
                "lock": alg, "topology": c.topology,
                "throughput": round(c.result.throughput, 4),
                "miss_per_episode": round(c.result.miss_per_episode, 2),
                "remote_per_episode":
                    round(c.result.remote_per_episode, 2),
                "latency": round(c.result.latency, 1),
            })
        if cfg.verbose:
            base = g.cell(topology=machines[0].name).result
            worst = max(g.results(), key=lambda r: r.remote_per_episode)
            emit(f"topology/{alg}",
                 (time.time() - t0) * 1e6 / max(base.episodes, 1),
                 f"smp={base.throughput:.3f}/kcyc "
                 f"worst_remote/ep={worst.remote_per_episode:.2f} "
                 f"jits={g.compiles}")

    # remote-miss scaling vs node count: flat machines as pure data, so
    # the whole node axis shares one jit per lock
    focus = [a for a in TOPOLOGY_FOCUS if a in algs] or list(algs[:1])
    node_series = []
    for alg in focus:
        g = sweep.cached_grid(
            alg, seeds=seeds,
            topologies=[CostModel(n_nodes=k)
                        for k in TOPOLOGY_NODE_COUNTS],
            workloads=[wl], threads=[t_hi])
        compiles += g.compiles
        grids += 1
        points += len(TOPOLOGY_NODE_COUNTS) * cfg.n_replicas
        node_series.append({"label": alg, "points": [
            {"nodes": k,
             "remote_per_episode": round(c.result.remote_per_episode, 3),
             "throughput": round(c.result.throughput, 4)}
            for k, c in zip(TOPOLOGY_NODE_COUNTS, g.cells)]})

    placements = {machines[1].name: "contiguous",
                  machines[-1].name: "interleaved"}
    placement_rows = [
        {"lock": r["lock"], "placement": placements[r["topology"]],
         "throughput": r["throughput"],
         "remote_per_episode": r["remote_per_episode"]}
        for r in grid_rows
        if r["lock"] in focus and r["topology"] in placements]

    stats = {
        "grids": grids, "grid_points": points, "xla_compiles": compiles,
        "compiles_per_grid": round(compiles / max(grids, 1), 3),
        "machines": [m.name for m in machines],
        "threads": t_hi,
    }
    if cfg.verbose:
        emit("topology/compiles", 0.0,
             f"{compiles} jits for {grids} grids ({points} grid points)")
    return [
        table_experiment(
            "topology_grid",
            f"Topology grid — every lock on SMP / 2- and 4-node NUMA / "
            f"clustered-CCX / interleaved-NUMA machines "
            f"(T={t_hi}, degenerate local CS; one jit per lock)",
            ["lock", "topology", "throughput", "miss_per_episode",
             "remote_per_episode", "latency"], grid_rows),
        sweep_experiment(
            "topology_remote_scaling",
            "Remote misses per episode vs NUMA node count — "
            "queue locks stay O(1)-remote while global spinning scales "
            "(paper §3 Maximum Remote Misses)", "nodes", node_series),
        table_experiment(
            "topology_placement",
            f"Placement sensitivity — contiguous vs interleaved thread "
            f"pinning on the 2-node NUMA machine (T={t_hi})",
            ["lock", "placement", "throughput", "remote_per_episode"],
            placement_rows),
        scalars_experiment(
            "topology_compile",
            "Batched-grid compile accounting — SimEngine.grid shares one "
            "XLA program across the seed x topology axes", stats),
    ]


# Locks whose degradation the hostile suite contrasts: pure spinners
# (collapse under oversubscription), queue spinners (holder preemption
# stalls the relay), the parking hybrid (graceful), and the timed-wait
# abortable variants.
HOSTILE_LOCKS = ("reciprocating", "ticket", "mcs", "spin_then_park",
                 "reciprocating_abortable", "mcs_timeout")
HOSTILE_QUANTA = (1200, 2500)
HOSTILE_OVERSUB = (2, 4)
# escalating hostility for the abort-rate ladder
HOSTILE_LADDER = ("dedicated", "fair-2x", "fair-4x", "holder-bane",
                  "lhp:800x200x4")


def hostile_schedulers(quick: bool) -> list:
    """The quantum × oversubscription grid as shorthand names, dedicated
    first (the baseline column)."""
    quanta = HOSTILE_QUANTA[-1:] if quick else HOSTILE_QUANTA
    ovs = HOSTILE_OVERSUB[-1:] if quick else HOSTILE_OVERSUB
    return ["dedicated"] + [f"fair:{q}x{r}" for q in quanta for r in ovs]


def build_hostile(cfg: BenchConfig) -> list:
    """Hostile-OS suite (DESIGN.md §L1 "Scheduler model"): who degrades
    gracefully when the OS preempts and oversubscribes. Every lock's
    whole scheduler grid is ONE ``SimEngine.grid`` call — schedulers are
    stacked ``LoweredSched`` data, so the axis adds zero XLA traces
    (``hostile_compile`` exports the accounting; CI pins
    ``compiles_per_grid <= 1``)."""
    algs = _algs(cfg, HOSTILE_LOCKS)
    t_hi = min(16, max(max(cfg.threads), 4))
    seeds = range(cfg.seed0, cfg.seed0 + cfg.n_replicas)
    wl = Workload(0, True, cfg.n_steps, label="max_contention")
    scheds = hostile_schedulers(cfg.quick)

    grid_rows, compiles, grids, points = [], 0, 0, 0
    base_thr: dict = {}
    for alg in algs:
        t0 = time.time()
        g = sweep.cached_grid(alg, seeds=seeds, schedulers=scheds,
                              workloads=[wl], threads=[t_hi])
        compiles += g.compiles
        grids += 1
        points += len(scheds) * cfg.n_replicas
        base = g.cell(scheduler="dedicated").result
        base_thr[alg] = base.throughput
        for c in g.cells:
            r = c.result
            grid_rows.append({
                "lock": alg, "scheduler": c.scheduler,
                "throughput": round(r.throughput, 4),
                "vs_dedicated": round(r.throughput
                                      / max(base.throughput, 1e-9), 3),
                "latency": round(r.latency, 1),
                "unfairness": round(r.unfairness, 3),
                "preempts": r.preempts,
                "aborts": r.aborts,
            })
        if cfg.verbose:
            worst = min(g.results(), key=lambda r: r.throughput)
            emit(f"hostile/{alg}",
                 (time.time() - t0) * 1e6 / max(base.episodes, 1),
                 f"dedicated={base.throughput:.3f}/kcyc "
                 f"worst={worst.throughput:.3f}/kcyc jits={g.compiles}")

    # lock-holder-preemption stress: same quantum/oversubscription, with
    # and without the tight lock-held slice — the LHP delta isolates how
    # much of the collapse is the *holder* vanishing mid-CS.
    lhp_rows = []
    lhp_pair = ["fair:2500x2", "lhp:2500x600x2"]
    for alg in algs:
        g = sweep.cached_grid(alg, seeds=seeds, schedulers=lhp_pair,
                              workloads=[wl], threads=[t_hi])
        compiles += g.compiles
        grids += 1
        points += len(lhp_pair) * cfg.n_replicas
        fair, lhp = (g.cell(scheduler=s).result for s in lhp_pair)
        lhp_rows.append({
            "lock": alg,
            "fair_throughput": round(fair.throughput, 4),
            "lhp_throughput": round(lhp.throughput, 4),
            "lhp_penalty": round(fair.throughput
                                 / max(lhp.throughput, 1e-9), 3),
            "lhp_preempts": lhp.preempts,
            "lhp_latency": round(lhp.latency, 1),
        })
        if cfg.verbose:
            emit(f"hostile/lhp_{alg}", 0.0,
                 f"penalty={lhp_rows[-1]['lhp_penalty']}x "
                 f"preempts={lhp.preempts}")

    # abort-rate ladder: the timed-wait locks up the hostility scale —
    # aborts should be ~0 on the dedicated machine and climb with
    # preemption pressure while episodes keep flowing.
    abort_rows = []
    ladder = HOSTILE_LADDER[::2] if cfg.quick else HOSTILE_LADDER
    from repro.core.locks.programs import ABORTABLE_VARIANTS
    for alg in [a for a in algs if a in ABORTABLE_VARIANTS]:
        g = sweep.cached_grid(alg, seeds=seeds, schedulers=list(ladder),
                              workloads=[wl], threads=[t_hi])
        compiles += g.compiles
        grids += 1
        points += len(ladder) * cfg.n_replicas
        for c in g.cells:
            r = c.result
            abort_rows.append({
                "lock": alg, "scheduler": c.scheduler,
                "episodes": r.episodes, "aborts": r.aborts,
                "abort_rate": round(r.aborts
                                    / max(r.episodes + r.aborts, 1), 4),
                "throughput": round(r.throughput, 4),
                "preempts": r.preempts,
            })
        if cfg.verbose:
            emit(f"hostile/aborts_{alg}", 0.0,
                 " ".join(f"{row['scheduler']}={row['abort_rate']:.2%}"
                          for row in abort_rows if row["lock"] == alg))

    stats = {
        "grids": grids, "grid_points": points, "xla_compiles": compiles,
        "compiles_per_grid": round(compiles / max(grids, 1), 3),
        "schedulers": scheds, "threads": t_hi,
    }
    if cfg.verbose:
        emit("hostile/compiles", 0.0,
             f"{compiles} jits for {grids} grids ({points} grid points)")
    return [
        table_experiment(
            "hostile_grid",
            f"Hostile-OS grid — locks × (quantum × oversubscription) at "
            f"T={t_hi}, maximal contention: spinners collapse under "
            f"timeslicing, spin-then-park degrades gracefully "
            f"(vs_dedicated = throughput relative to the pinned machine)",
            ["lock", "scheduler", "throughput", "vs_dedicated", "latency",
             "unfairness", "preempts", "aborts"], grid_rows),
        table_experiment(
            "hostile_lhp",
            f"Lock-holder preemption — fair:2500x2 vs the same schedule "
            f"with a 600-cycle lock-held slice (T={t_hi}); lhp_penalty = "
            f"fair/lhp throughput ratio",
            ["lock", "fair_throughput", "lhp_throughput", "lhp_penalty",
             "lhp_preempts", "lhp_latency"], lhp_rows),
        table_experiment(
            "hostile_abort",
            f"Abortable acquisition — timed-wait locks up the hostility "
            f"ladder (T={t_hi}): abort rate climbs with preemption "
            f"pressure while mutual exclusion and progress hold",
            ["lock", "scheduler", "episodes", "aborts", "abort_rate",
             "throughput", "preempts"], abort_rows),
        scalars_experiment(
            "hostile_compile",
            "Batched-grid compile accounting — the scheduler axis is "
            "stacked LoweredSched data under the topology-grid jit",
            stats),
    ]


def build_fairness(cfg: BenchConfig) -> list:
    t0 = time.time()
    n_ops = 1500 if cfg.quick else 8000
    ref = sweep.reference_fairness(n_threads=5, n_ops=n_ops)
    values = {
        "table2_cycle": ref["cycle_str"],
        "table2_cycle_admissions_sorted": ref["cycle_admissions_sorted"],
        "reference_unfairness": ref["unfairness"],
        "mitigated_unfairness":
            round(sweep.mitigated_unfairness(
                n_events=800 if cfg.quick else 4000, seed=cfg.seed0), 3),
    }
    for alg in ("reciprocating", "ticket", "retrograde"):
        r = sweep.bench_cell(alg, 5, cfg, n_nodes=1)
        values[f"machine_unfairness_{alg}"] = round(r.unfairness, 3)
    if cfg.verbose:
        emit("fairness/table2", (time.time() - t0) * 1e6 / n_ops,
             f"cycle={values['table2_cycle']} "
             f"unfair={values['reference_unfairness']}")

    n_events = 400 if cfg.quick else 2000
    bins, series, stat_rows = sweep.bypass_histograms(
        ADMISSION_POLICIES, n_threads=8, n_events=n_events, seed=cfg.seed0)
    if cfg.verbose:
        for r in stat_rows:
            emit(f"fairness/bypass_{r['policy']}", 0.0,
                 f"max_single={r['max_bypass_by_single_thread']} "
                 f"bound={r['theoretical_single_thread_bound']} "
                 f"outstanding={r['max_outstanding_unserved']}")
    return [
        scalars_experiment(
            "fairness", "Fairness — Table 2 palindromic cycle, §9 "
            "long-run unfairness, §9.4 mitigation", values),
        hist_experiment(
            "bypass_hist",
            "Bounded bypass — per-wait overtake counts by admission "
            "policy (closed loop, 8 threads)", bins, series),
        table_experiment(
            "bypass_bounds",
            "Bounded bypass — observed vs theoretical single-thread "
            "bounds (paper §2)",
            ["policy", "completed_waits", "mean_bypass",
             "max_bypass_per_wait", "max_bypass_by_single_thread",
             "max_outstanding_unserved",
             "theoretical_single_thread_bound"], stat_rows),
    ]


def build_residency(cfg: BenchConfig) -> list:
    """App. C: residual cache residency, palindrome vs FIFO (Jensen)."""
    def schedule_residency(schedule, n, lam, cycles=200):
        last = {t: None for t in range(n)}
        acc = {t: [] for t in range(n)}
        step = 0
        for _ in range(cycles):
            for t in schedule:
                if last[t] is not None:
                    acc[t].append(np.exp(-(step - last[t]) * lam))
                last[t] = step
                step += 1
        return np.array([np.mean(acc[t]) for t in range(n)])

    n, lam = 5, 0.15
    fifo = list(range(n))
    palin = list(range(n)) + list(reversed(range(n)))
    r_fifo = schedule_residency(fifo, n, lam)
    r_palin = schedule_residency(palin, n, lam)
    values = {
        "lambda": lam,
        "fifo_mean": round(float(r_fifo.mean()), 4),
        "palindrome_mean": round(float(r_palin.mean()), 4),
        "palindrome_wins": bool(r_palin.mean() >= r_fifo.mean()),
        "per_party_never_worse": bool((r_palin >= r_fifo - 1e-12).all()),
        "disparity_palindrome": round(float(r_palin.max() / r_palin.min()),
                                      4),
    }
    if cfg.verbose:
        emit("residency/jensen", 0.0,
             f"palin={values['palindrome_mean']:.4f} "
             f"fifo={values['fifo_mean']:.4f} "
             f"wins={values['palindrome_wins']}")
    rows = []
    for lam_s in (0.02, 0.05, 0.1, 0.2, 0.4):
        a = float(schedule_residency(palin, n, lam_s).mean())
        b = float(schedule_residency(fifo, n, lam_s).mean())
        rows.append({"lambda": lam_s, "palindrome": round(a, 4),
                     "fifo": round(b, 4), "advantage": round(a / b, 4)})
    return [
        scalars_experiment(
            "residency", "Appendix C — residual residency under the "
            "palindromic admission schedule", values),
        table_experiment(
            "residency_sweep", "Appendix C — palindrome advantage vs "
            "residency decay rate",
            ["lambda", "palindrome", "fifo", "advantage"], rows),
    ]


def scheduler_drive(policy: str, *, n_req: int = 600, mean_gap: float = 14.0,
                    families: int = 64, pool: int = 96, seed: int = 0) -> dict:
    """Bursty shared-prefix workload against the continuous batcher: a
    family arrives as a burst of 2-6 requests close together (users
    iterating on one prompt) — the regime where admission order interacts
    with prefix residency (SERVING.md §4). ``mean_gap`` sets the offered
    load (mean burst size is 4 requests, so load ≈ 4/mean_gap req/step).
    Runs on the same ``ServeCore`` + ``PagedKVPool`` the model engine
    uses; the summary includes the pool's eviction count."""
    from repro.serve.scheduler import ContinuousBatcher, Request
    sched = ContinuousBatcher(policy=policy, max_batch=4, pool_blocks=pool,
                              seed=seed)
    rng = np.random.default_rng(seed)
    t, i = 0.0, 0
    while i < n_req:
        t += float(rng.exponential(mean_gap))
        fam = int(rng.integers(0, families))
        for _ in range(int(rng.integers(2, 7))):
            if i >= n_req:
                break
            sched.submit(Request(
                rid=i, arrival=t + float(rng.exponential(2.0)),
                prefix_id=fam, prefix_blocks=16, prompt_blocks=2,
                decode_tokens=int(rng.integers(4, 16))))
            i += 1
    sched.drain()
    s = sched.stats.summary()
    s["pool_evictions"] = sched.pool.stats.evictions
    return s


def build_scheduler(cfg: BenchConfig) -> list:
    """Beyond-paper: reciprocating admission in the serving scheduler
    (DESIGN.md §L3)."""
    drive = scheduler_drive
    n_req = 120 if cfg.quick else 600
    n_seeds = 1 if cfg.quick else 3
    rows = []
    for policy in ADMISSION_POLICIES:
        agg: dict = {}
        t0 = time.time()
        for seed in range(n_seeds):
            for k, v in drive(policy, n_req=n_req, seed=seed).items():
                agg.setdefault(k, []).append(v)
        row = {"policy": policy}
        row.update({k: round(float(np.mean(v)), 4) for k, v in agg.items()})
        rows.append(row)
        if cfg.verbose:
            emit(f"scheduler/{policy}",
                 (time.time() - t0) / n_seeds * 1e6 / n_req,
                 f"hit={row.get('prefix_hit_rate', 0):.3f} "
                 f"p99wait={row.get('p99_wait', 0):.1f}")
    cols = ["policy"] + [k for k in rows[0] if k != "policy"]
    return [table_experiment(
        "scheduler_policies",
        "Serving scheduler — admission policy comparison on a bursty "
        "shared-prefix workload", cols, rows)]


SERVE_GAPS_FULL = (28.0, 14.0, 7.0, 4.0)    # mean inter-burst gap (steps)
SERVE_GAPS_QUICK = (14.0, 7.0)
SERVE_METRICS = ("throughput_rps", "p99_wait", "max_wait", "p99_latency",
                 "mean_wait", "prefix_hit_rate", "pool_evictions")


def static_batch_slot_steps(done: list, max_batch: int) -> int:
    """Decode slot-steps the old detached-segment engine would burn:
    submission-order segments of ``max_batch``, every slot riding to the
    segment's longest request."""
    reqs = sorted(done, key=lambda r: r.rid)
    return sum(len(seg) * max(len(r.out) for r in seg)
               for seg in (reqs[i:i + max_batch]
                           for i in range(0, len(reqs), max_batch)))


def serve_engine_smoke(seed: int = 0) -> dict:
    """Model-backed serving smoke (SERVING.md §6): the paged continuous
    batcher on a reduced starcoder2-3b, two shared-prefix families, mixed
    ``max_new`` so early exit and per-step admission are both exercised."""
    import jax

    from repro.configs import get_config, smoke_config
    from repro.models import model as M_
    from repro.serve.engine import GenRequest, InferenceEngine

    mcfg = smoke_config(get_config("starcoder2-3b")).replace(
        n_layers=2, vocab_size=256)
    params = M_.init_params(mcfg, jax.random.PRNGKey(seed))
    eng = InferenceEngine(mcfg, params, policy="reciprocating",
                          max_batch=4, max_seq=64, block_size=8)
    rng = np.random.default_rng(seed)
    shared = {f: rng.integers(1, 97, 16, dtype=np.int32) for f in range(2)}
    t0 = time.time()
    for i in range(8):
        fam = i % 2
        toks = np.concatenate(
            [shared[fam], rng.integers(1, 97, 4, dtype=np.int32)])
        eng.submit(GenRequest(rid=i, tokens=toks, prefix_id=fam,
                              prefix_len=16,
                              max_new=int(rng.integers(2, 9))))
    done = eng.run()
    wall = time.time() - t0
    gen = sum(len(r.out) for r in done)
    c = eng.counters
    naive = static_batch_slot_steps(done, max_batch=4)
    return {
        "requests": len(done),
        "generated_tokens": gen,
        "scheduler_steps": int(eng.core.time),
        "decode_batches": c.decode_batches,
        "slot_steps": c.slot_steps,
        "slot_steps_static_batch": naive,
        "early_exit_savings":
            round(1.0 - c.slot_steps / max(naive, 1), 4),
        "mean_prefill_hit":
            round(float(np.mean([r.prefill_hit for r in done])), 4),
        "pool": eng.pool.stats.to_dict(),
        "wall_s": round(wall, 2),
        "tokens_per_s": round(gen / max(wall, 1e-9), 2),
    }


def build_serve(cfg: BenchConfig) -> list:
    """Serving suite (SERVING.md §6): policy × offered-load sweep on the
    unified scheduler core, pool/starvation table at the heaviest load,
    and (full runs only) the model-backed paged-engine smoke."""
    gaps = SERVE_GAPS_QUICK if cfg.quick else SERVE_GAPS_FULL
    n_req = 120 if cfg.quick else 600
    n_seeds = 1 if cfg.quick else 3
    series, heavy_rows = [], []
    for policy in ADMISSION_POLICIES:
        t0 = time.time()
        pts = []
        for gap in gaps:
            agg: dict = {}
            for seed in range(n_seeds):
                d = scheduler_drive(policy, n_req=n_req, mean_gap=gap,
                                    seed=cfg.seed0 + seed)
                for k in SERVE_METRICS:
                    agg.setdefault(k, []).append(d[k])
            pt = {"offered_load": round(4.0 / gap, 3)}
            pt.update({k: round(float(np.mean(v)), 4)
                       for k, v in agg.items()})
            pts.append(pt)
        series.append({"label": policy, "points": pts})
        heavy = dict(pts[-1])
        heavy_rows.append({"policy": policy, **heavy})
        if cfg.verbose:
            emit(f"serve/{policy}",
                 (time.time() - t0) * 1e6 / (len(gaps) * n_seeds * n_req),
                 f"hit={pts[-1]['prefix_hit_rate']:.3f} "
                 f"p99wait={pts[-1]['p99_wait']:.1f} "
                 f"maxwait={pts[-1]['max_wait']:.1f}")
    exps = [
        sweep_experiment(
            "serve_policy_load",
            "Serving — throughput / tail wait / prefix hit vs offered "
            "load × admission policy (unified scheduler core, paged-KV "
            "pool)", "offered_load", series,
            meta={"series_label": "policy"}),
        table_experiment(
            "serve_pool",
            "Serving — starvation and paged-KV pool behaviour at the "
            "heaviest offered load",
            ["policy", "offered_load"] + list(SERVE_METRICS), heavy_rows),
    ]
    if not cfg.quick:
        t0 = time.time()
        vals = serve_engine_smoke(cfg.seed0)
        if cfg.verbose:
            emit("serve/engine_smoke", (time.time() - t0) * 1e6
                 / max(vals["generated_tokens"], 1),
                 f"steps={vals['scheduler_steps']} "
                 f"early_exit={vals['early_exit_savings']:.2%} "
                 f"hit={vals['mean_prefill_hit']:.2f}")
        exps.append(scalars_experiment(
            "serve_engine_smoke",
            "Serving — model-backed paged continuous-batching engine "
            "smoke (reduced starcoder2-3b, CPU)", vals))
    return exps


GATEWAY_ROUTERS = ("round_robin", "random", "least_loaded", "prefix",
                   "reciprocating")
GATEWAY_METRICS = ("hit_rate", "mean_ttft", "p99_ttft", "mean_tpot",
                   "goodput_tok_per_step", "load_imbalance", "mean_wait")
#: Fleet shape shared by every gateway experiment: 8 replicas x 8 slots,
#: per-replica pools sized so the tenant working set (~160 tenants x
#: 4-12 shared blocks) fits the fleet aggregate but NOT one pool —
#: the regime where routing decides the global hit rate (SERVING.md §8).
GATEWAY_FLEET = {"n_replicas": 8, "max_slots": 8, "pool_blocks": 160,
                 "block_tokens": 16, "prefill_cost_per_block": 1.0,
                 "load_penalty": 4.0}


def fleet_drive(router: str, *, n_req: int, seed: int = 0,
                burst_rate: float = 0.2) -> dict:
    """One trace-to-drain fleet run, fronted by the experiment cache: a
    gateway drive is a pure function of (fleet shape, router, seeded
    trace spec), so its summary is content-addressed exactly like a sim
    grid cell (bench/cache.py) and warm paper re-runs replay it."""
    import hashlib

    from repro.bench import cache as cachemod
    from repro.serve.gateway import FleetGateway
    from repro.serve.traces import TraceSpec, generate

    gw_kwargs = dict(GATEWAY_FLEET, router=router, seed=seed)
    trace_kwargs = {"n_requests": n_req, "burst_rate": burst_rate,
                    "seed": seed}
    store = cachemod.get_cache()
    key = hashlib.sha256(json.dumps(
        {"v": cachemod.CACHE_KEY_VERSION, "kind": "fleet_drive",
         "gw": gw_kwargs, "trace": trace_kwargs},
        sort_keys=True).encode()).hexdigest()
    s = store.get(key)
    if s is None:
        if store.enabled:
            store.stats.misses += 1
        t0 = time.time()
        gw = FleetGateway(**gw_kwargs)
        s = gw.run(generate(TraceSpec(**trace_kwargs)))
        wall = time.time() - t0
        s["wall_s"] = round(wall, 3)
        s["req_per_s"] = round(n_req / max(wall, 1e-9), 1)
        if store.enabled:
            store.put(key, s)
    elif store.enabled:
        store.stats.hits += 1
    # O(requests) bookkeeping bound (serve/core.py): every request costs
    # exactly one arrival-heap pop and one slot retirement, regardless
    # of trace length — the micro-assert that keeps million-request
    # traces from going quadratic again.
    assert s["bookkeeping_ops"] == 2 * n_req, (
        f"bookkeeping ops {s['bookkeeping_ops']} != 2*{n_req}")
    return s


def build_gateway(cfg: BenchConfig) -> list:
    """Fleet tier (SERVING.md §8): router comparison table, offered-load
    sweep, and the at-scale prefix-vs-baselines run (100k requests
    quick, 1M full)."""
    seed = cfg.seed0
    n_table = 10_000 if cfg.quick else 100_000
    n_sweep = 4_000 if cfg.quick else 20_000
    n_scale = 100_000 if cfg.quick else 1_000_000
    rates = (0.12, 0.2) if cfg.quick else (0.1, 0.15, 0.2, 0.25)

    rows = []
    for router in GATEWAY_ROUTERS:
        t0 = time.time()
        s = fleet_drive(router, n_req=n_table, seed=seed)
        rows.append({"router": router,
                     **{k: round(float(s[k]), 4) for k in GATEWAY_METRICS},
                     "tree_nodes": s["tree_nodes"]})
        if cfg.verbose:
            emit(f"gateway/{router}", (time.time() - t0) * 1e6 / n_table,
                 f"hit={s['hit_rate']:.3f} ttft={s['mean_ttft']:.1f} "
                 f"imb={s['load_imbalance']:.2f}")

    series = []
    for router in GATEWAY_ROUTERS:
        pts = []
        for rate in rates:
            s = fleet_drive(router, n_req=n_sweep, seed=seed,
                            burst_rate=rate)
            pt = {"offered_load": round(rate * 7.0, 3)}
            pt.update({k: round(float(s[k]), 4) for k in GATEWAY_METRICS})
            pts.append(pt)
        series.append({"label": router, "points": pts})

    scale_routers = ("prefix", "random", "round_robin")
    scale: dict = {"n_requests": n_scale}
    for router in scale_routers:
        t0 = time.time()
        s = fleet_drive(router, n_req=n_scale, seed=seed)
        scale[router] = {k: round(float(s[k]), 4) for k in GATEWAY_METRICS}
        scale[router]["bookkeeping_ops"] = s["bookkeeping_ops"]
        scale[router]["req_per_s"] = s["req_per_s"]
        if cfg.verbose:
            emit(f"gateway/scale_{router}",
                 (time.time() - t0) * 1e6 / n_scale,
                 f"n={n_scale} hit={s['hit_rate']:.3f} "
                 f"ttft={s['mean_ttft']:.1f}")

    return [
        table_experiment(
            "gateway_routers",
            "Fleet gateway — routing policy comparison on the seeded "
            "multi-tenant trace (8 replicas, global radix prefix tree)",
            ["router"] + list(GATEWAY_METRICS) + ["tree_nodes"], rows),
        sweep_experiment(
            "gateway_load",
            "Fleet gateway — TTFT / hit rate / goodput vs offered load "
            "× router", "offered_load", series,
            meta={"series_label": "router"}),
        scalars_experiment(
            "gateway_scale",
            "Fleet gateway — prefix routing vs baselines at scale "
            "(the >=100k-request trace; 1M on full runs) with the "
            "O(requests) bookkeeping bound asserted", scale),
    ]


def build_kernels(cfg: BenchConfig) -> list:
    """Beyond-paper: serpentine-vs-ascending structural DMA accounting."""
    from repro.configs import get_config
    from repro.kernels.flash_attention import serpentine_savings

    cases = [
        ("granite-3-2b", 4096, 4096, 128),
        ("mixtral-8x7b", 4096, 4096, 128),
        ("starcoder2-7b", 32768, 32768, 256),
        ("deepseek-v2-236b", 4096, 4096, 128),
        ("whisper-large-v3", 4096, 1536, 128),
    ]
    rows = []
    for arch, sq, sk, blk in cases:
        cfg_a = get_config(arch)
        n_q, n_kv = sq // blk, sk // blk
        s = serpentine_savings(n_q, n_kv)
        kv_heads = max(cfg_a.n_kv_heads, 1)
        block_bytes = blk * cfg_a.hd * 2 * 2
        saved = (s["ascending"] - s["serpentine"]) * block_bytes * kv_heads
        rows.append({
            "arch": arch, "grid": f"{n_q}x{n_kv}",
            "ascending_fetches": int(s["ascending"]),
            "serpentine_fetches": int(s["serpentine"]),
            "saved_fraction": round(float(s["saved_fraction"]), 4),
            "hbm_mb_saved_per_batch_row": round(saved / 1e6, 2),
        })
        if cfg.verbose:
            emit(f"kernel/serpentine/{arch}", 0.0,
                 f"saved={s['saved_fraction'] * 100:.1f}% of KV fetches")
    return [table_experiment(
        "kernel_serpentine",
        "Serpentine flash-attention schedule — structural KV-fetch "
        "savings",
        ["arch", "grid", "ascending_fetches", "serpentine_fetches",
         "saved_fraction", "hbm_mb_saved_per_batch_row"], rows)]


def build_roofline(cfg: BenchConfig, artifacts_dir: str | None = None) -> list:
    """Aggregate ``repro.launch.dryrun`` artifacts (if any were produced)
    into the roofline table; an empty artifacts dir yields an empty table
    rather than an error."""
    art = artifacts_dir or os.environ.get(
        "REPRO_BENCH_ARTIFACTS",
        os.path.join("benchmarks", "artifacts"))
    rows = []
    for f in sorted(glob.glob(os.path.join(art, "dryrun_*_single.json"))):
        with open(f) as fh:
            d = json.load(fh)
        if d.get("status") != "ok":
            continue
        t = d["roofline_seconds"]
        bound = max(t.values())
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "compute_ms": round(t["compute"] * 1e3, 2),
            "memory_ms": round(t["memory"] * 1e3, 2),
            "collective_ms": round(t["collective"] * 1e3, 2),
            "dominant": d["dominant"],
            "roofline_fraction": round(t["compute"] / bound, 4),
            "useful_flop_ratio": (round(d["useful_flop_ratio"], 4)
                                  if "useful_flop_ratio" in d else None),
            "peak_gb": round(d["peak_bytes_per_device"] / 1e9, 2),
            "fits_16gb": d["fits_16gb"],
        })
    if cfg.verbose:
        emit("roofline/cells", 0.0, f"{len(rows)} single-pod cells")
    return [table_experiment(
        "roofline", "Roofline — dry-run cell aggregation (single-pod)",
        ["arch", "shape", "compute_ms", "memory_ms", "collective_ms",
         "dominant", "roofline_fraction", "useful_flop_ratio", "peak_gb",
         "fits_16gb"], rows,
        meta={"artifacts_dir": art})]


def build_verify(cfg: BenchConfig) -> list:
    """The verified-property matrix as a table experiment: the paper's
    lock-comparison table with every cell machine-checked — structural
    passes from ``core/locks/cfg.py`` always; the exhaustive T=2 model
    check from ``core/locks/verify.py`` unless ``quick`` (CI smoke runs
    keep the structural column real but skip the interleaving
    enumeration)."""
    from repro.core.locks import verify as verify_mod
    t0 = time.time()
    verdicts = verify_mod.verify_all(names=cfg.algs, model=not cfg.quick)
    bad = [v.name for v in verdicts if not v.ok]
    emit("verify.matrix", (time.time() - t0) * 1e6,
         f"locks={len(verdicts)} failed={len(bad)}")
    if bad:
        raise RuntimeError(
            f"verification failed for {bad} — run `python -m repro.bench "
            "verify` for the counterexample traces")
    note = ("Structural properties proven per spec by `core/locks/cfg.py`"
            " at compile time; interleaving properties (mutual exclusion,"
            " deadlock freedom, no lost wakeups, bounded bypass) "
            "certified by exhaustively enumerating every schedule at the "
            "stated scope (`core/locks/verify.py`)."
            if not cfg.quick else
            "Structural passes only (`--quick`): run `python -m "
            "repro.bench verify` for the model-check column.")
    return [table_experiment(
        "verify_matrix", report.VERIFY_HEADER.lstrip("# "),
        verify_mod.matrix_columns(), verify_mod.matrix_rows(verdicts),
        meta={"note": note})]


# --- registered suites -------------------------------------------------------

register("mutexbench", "MutexBench thread sweeps (Fig. 1a/1b)",
         "Throughput/miss/latency vs threads for every lock program, "
         "maximal contention and random NCS.")(build_fig1)
register("atomics", "Lock-striped atomics (Fig. 2)",
         "std::atomic<struct> analogue: shared-rw CS, empty NCS.")(build_fig2)
register("kvstore", "KV-store readrandom (Fig. 3)",
         "Coarse lock over read-only lookups with random key-gen "
         "NCS.")(build_fig3)
register("coherence", "Coherence traffic (Table 1)",
         "Invalidations / misses / NUMA-remote misses per contended "
         "episode at T=10.")(build_table1)
register("locks-ext", "Extended lock zoo (beyond paper, DESIGN.md §L2)",
         "DSL-authored lock variants (hapax, fissile, spin_then_park) "
         "vs the paper baselines: thread sweep, phase/coherence profile "
         "with the observed bypass bound, and spin_then_park park-cost "
         "sensitivity.")(build_locks_ext)
register("topology", "Machine-topology sweep (DESIGN.md §L1)",
         "Every lock across SMP / NUMA / clustered-CCX machine models "
         "via SimEngine.grid: throughput and remote-miss scaling, "
         "placement sensitivity, and the one-jit-per-grid-shape compile "
         "accounting.")(build_topology)
register("hostile", "Hostile-OS scheduler sweep (beyond paper, "
         "DESIGN.md §L1)",
         "Preemption, oversubscription and lock-holder-preemption "
         "stress via core/sim/sched.py: locks × quantum × oversub grid, "
         "LHP penalty table, and the abort-rate ladder for the "
         "timed-wait locks.")(build_hostile)
register("fairness", "Fairness and bounded bypass (Table 2, §9)",
         "Palindromic admission cycle, long-run unfairness, §9.4 "
         "mitigation, and bypass histograms over core.admission "
         "policies.")(build_fairness)
register("residency", "Cache residency (App. C)",
         "Residual-residency decay model: palindrome vs FIFO under "
         "Jensen's inequality.")(build_residency)
register("scheduler", "Serving-scheduler admission (beyond paper)",
         "Reciprocating admission vs FIFO/LIFO in the continuous "
         "batcher.")(build_scheduler)
register("serve", "Serving engine (beyond paper, docs/SERVING.md)",
         "Policy × offered-load sweep on the unified continuous-batching "
         "core with the paged-KV pool, plus the model-backed engine "
         "smoke (full runs).")(build_serve)
register("gateway", "Fleet serving gateway (beyond paper, "
         "docs/SERVING.md §8)",
         "Multi-replica gateway with prefix-aware routing over a global "
         "radix prefix tree: router comparison table, offered-load "
         "sweep, and the 100k/1M-request at-scale run with the "
         "O(requests) bookkeeping bound asserted.")(build_gateway)
register("measured", "Measured tier: Pallas-backend paper sweeps "
         "(DESIGN.md §L2)",
         "Fig 1-3 style throughput/latency sweeps executed as real "
         "Pallas kernels over the device atomics layer (interpret-mode "
         "fallback on CPU), the sim-vs-Pallas backend-agreement table, "
         "and the CostModel calibration error table "
         "(bench/calibrate.py).")(build_measured)
register("kernels", "Serpentine kernel accounting (beyond paper)",
         "Structural KV-fetch savings of the serpentine flash-attention "
         "schedule.")(build_kernels)
register("roofline", "Roofline aggregation",
         "Aggregates repro.launch.dryrun artifacts into the roofline "
         "table.")(build_roofline)
register("verify", "Verified lock properties (DESIGN.md §L2)",
         "The paper's lock-comparison table, machine-checked: structural "
         "proofs (constant-time doorway/release, spin locality, waiting "
         "footprint) from core/locks/cfg.py plus the exhaustive "
         "small-scope model check (core/locks/verify.py).")(build_verify)


@register("paper", "Paper reproduction (Figs 1-3, Table 1, fairness)",
          "End-to-end reproduction of the paper's evaluation: "
          "throughput-vs-threads for every lock program, coherence "
          "traffic, fairness and bounded-bypass histograms — plus the "
          "beyond-paper extended lock zoo (locks-ext), machine-topology "
          "(topology), hostile-OS scheduler (hostile), serving "
          "(docs/SERVING.md), fleet-gateway (SERVING.md §8) and "
          "measured Pallas-backend (bench/measured.py) sections.",
          tags=("paper",))
def build_paper(cfg: BenchConfig) -> list:
    exps = []
    cells: dict = {}
    exps += build_fig1(cfg, on_result=lambda a, t, r:
                       cells.__setitem__((a, t), r))
    exps += build_fig2(cfg)
    exps += build_fig3(cfg)
    exps += build_table1(cfg)
    # locks-ext reuses Fig. 1a's max-contention curves and cells
    # (identical settings) and only simulates its park extras on top.
    fig1a = next(e for e in exps if e["name"] == "fig1a_max_contention")
    exps += build_locks_ext(cfg, reuse_series=fig1a["series"],
                            reuse_cells=cells)
    exps += build_topology(cfg)
    exps += build_hostile(cfg)
    exps += build_fairness(cfg)
    exps += build_serve(cfg)
    exps += build_gateway(cfg)
    exps += build_measured(cfg)
    exps += build_verify(cfg)
    return exps
