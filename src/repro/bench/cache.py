"""Content-addressed experiment cache for the sweep engine.

A grid cell — one (lock program, machine, scheduler, workload, seed
ensemble) simulation — is a pure function of its inputs, so its
``BenchResult`` can be cached on a canonical hash of those inputs and
replayed on later runs without touching XLA. This is what lets
``repro.bench run --suite paper`` skip every unchanged experiment on a
warm re-run (``--no-cache`` forces regeneration; ``BENCH_trend.json``
reports the hit rate).

The key has two layers:

* ``program_fingerprint(prog)`` — the *semantic* identity of a compiled
  ``Program``: per-handler jaxprs (traced on the machine's abstract
  state probe) plus closed-over constant bytes, the memory layout
  (``n_mem``/``home``/``init_mem``), register count, and the jax
  version. Step *labels* resolve to declaration-order program counters
  at compile time and docstrings never reach the jaxpr, so renaming a
  label or editing prose does NOT change the fingerprint — while any
  semantic edit (a different delta, a reordered step, a new memory
  word) does. Jaxprs are hashed *structurally* (primitive names,
  dataflow via first-encounter variable numbering, params with nested
  jaxprs expanded recursively) rather than via ``str(jaxpr)``: the
  pretty-printer collapses a repeated sub-jaxpr to a by-name reference
  (``jaxpr=_where``) whenever jax's internal trace caches happen to
  share the object, so the printed form depends on process history —
  the structural walk does not.
* ``cell_key(...)`` — the fingerprint plus everything else the
  simulation consumes: thread count, workload semantics (``ncs_max``,
  ``cs_mode``, ``n_steps`` — the display ``label`` is excluded), the
  raw bytes of the lowered topology matrices (``LoweredCost``) and
  scheduler scalars (``LoweredSched``), and the seed tuple. Topology
  and scheduler *names* are likewise excluded: two presets lowering to
  the same matrices are the same machine.

Sharding is deliberately NOT part of the key: sharded and unsharded
grids are bit-identical (``tests/test_sweep_cache.py`` pins this), so a
cell computed on a 4-device mesh may be served to a single-device run.

``CACHE_KEY_VERSION`` is the suite-version component of the key — bump
it whenever key semantics or the result encoding change, and every old
entry silently misses.

Storage is one JSON file per cell under ``<root>/<key[:2]>/<key>.json``
(root defaults to ``.bench_cache/``, overridable via ``--cache-dir`` or
``$REPRO_BENCH_CACHE_DIR``; ``$REPRO_BENCH_NO_CACHE=1`` disables the
cache entirely). ``BenchResult`` round-trips through
``result_to_doc``/``result_from_doc`` with explicit dtypes on the
ndarray fields, so a cache hit is bit-identical to the fresh run that
stored it.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import re
import tempfile
import weakref
from dataclasses import dataclass, field

import numpy as np

from repro.core.sim.api import BenchResult

__all__ = ["CACHE_KEY_VERSION", "program_fingerprint", "cell_key",
           "result_to_doc", "result_from_doc", "ExperimentCache",
           "CacheStats", "get_cache", "configure"]

#: Suite-version component of every key; bump on key/encoding changes.
CACHE_KEY_VERSION = 1

DEFAULT_ROOT = ".bench_cache"


# --- hashing ------------------------------------------------------------------

def _feed(h, *parts) -> None:
    for p in parts:
        h.update(str(p).encode())
        h.update(b"\x00")


def _feed_array(h, a) -> None:
    a = np.asarray(a)
    _feed(h, a.dtype.str, a.shape)
    h.update(a.tobytes())
    h.update(b"\x00")


_ADDR = re.compile(r"0x[0-9a-fA-F]+")


def _feed_jaxpr(h, jaxpr, ids) -> None:
    """Structural, sharing-insensitive jaxpr hash. ``str(jaxpr)`` is NOT
    stable across process history: the pretty-printer prints a repeated
    sub-jaxpr as ``jaxpr=<name>`` when jax's weakref trace caches make
    the two call sites share one object, and inline otherwise. Walking
    the structure and always recursing into nested jaxprs removes that
    dependence. ``ids`` numbers variables in first-encounter order so
    dataflow (not object identity) is what's hashed."""
    import jax

    def ref(v):
        if isinstance(v, jax.core.Literal):
            _feed(h, "lit", v.aval)
            _feed_array(h, v.val)
            return
        if v not in ids:
            ids[v] = len(ids)
        _feed(h, "v", ids[v], v.aval)

    _feed(h, "jaxpr", len(jaxpr.constvars), len(jaxpr.invars))
    for v in jaxpr.constvars:
        ref(v)
    for v in jaxpr.invars:
        ref(v)
    for eqn in jaxpr.eqns:
        _feed(h, "eqn", eqn.primitive.name, len(eqn.invars))
        for v in eqn.invars:
            ref(v)
        for k in sorted(eqn.params, key=str):
            _feed(h, "param", k)
            _feed_jaxpr_param(h, eqn.params[k], ids)
        for v in eqn.outvars:
            ref(v)
    _feed(h, "out")
    for v in jaxpr.outvars:
        ref(v)


def _feed_jaxpr_param(h, p, ids) -> None:
    import jax
    if isinstance(p, jax.core.ClosedJaxpr):
        _feed_jaxpr(h, p.jaxpr, dict(ids))
        for c in p.consts:
            _feed_array(h, c)
    elif isinstance(p, jax.core.Jaxpr):
        _feed_jaxpr(h, p, dict(ids))
    elif isinstance(p, (tuple, list)):
        _feed(h, "seq", len(p))
        for x in p:
            _feed_jaxpr_param(h, x, ids)
    else:
        # Shardings etc. stringify stably; strip any embedded object
        # addresses so reprs like <obj at 0x...> can't leak identity.
        _feed(h, _ADDR.sub("0x", str(p)))


# Fingerprints are cached per Program *object* (frozen dataclass, so
# weakref-able); the per-(threads, workload) program cache in SimEngine
# makes this one trace of each handler per process.
_FP_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _handler_digests(prog) -> list:
    """Per-handler canonical digests — the fingerprint's hash inputs at
    handler granularity, kept separable for mismatch postmortems."""
    import jax
    import jax.numpy as jnp
    # The machine's abstract per-thread state: (t, regs, result, rng).
    probe = (jnp.int32(0), jnp.zeros((prog.n_regs,), jnp.int32),
             jnp.int32(0), jnp.uint32(1))
    out = []
    for handler in prog.handlers:
        h = hashlib.sha256()
        closed = jax.make_jaxpr(handler)(*probe)
        _feed_jaxpr(h, closed.jaxpr, {})
        # Constants lift to constvars whose values the jaxpr walk sees
        # only as avals — hash the consts themselves by bytes.
        for c in closed.consts:
            _feed_array(h, c)
        out.append(h.hexdigest())
    return out


def program_fingerprint(prog) -> str:
    """Canonical semantic hash of a compiled ``Program`` (see module
    docstring for what is and isn't captured)."""
    with contextlib.suppress(KeyError, TypeError):
        return _FP_CACHE[prog]
    import jax
    h = hashlib.sha256()
    _feed(h, "repro.bench.cache", CACHE_KEY_VERSION, jax.__version__,
          int(prog.n_mem), int(prog.n_regs),
          tuple(prog.home), tuple(prog.init_mem))
    for d in _handler_digests(prog):
        _feed(h, d)
    fp = h.hexdigest()
    with contextlib.suppress(TypeError):
        # non-weakrefable custom Program stand-in
        _FP_CACHE[prog] = fp
    return fp


def cell_key(prog_fp: str, n_threads: int, workload, lowered_cost,
             lowered_sched, seeds) -> str:
    """Content key of one grid cell: program fingerprint + thread count
    + workload semantics + lowered machine/scheduler bytes + seeds."""
    h = hashlib.sha256()
    _feed(h, "cell", CACHE_KEY_VERSION, prog_fp, int(n_threads),
          int(workload.ncs_max), workload.cs_mode, int(workload.n_steps))
    for a in lowered_cost:
        _feed_array(h, a)
    for a in lowered_sched:
        _feed_array(h, a)
    _feed(h, tuple(int(s) for s in seeds))
    return h.hexdigest()


# --- BenchResult <-> JSON -----------------------------------------------------

_ARRAY_FIELDS = ("admissions", "admission_counts")
_SCALAR_FIELDS = ("name", "n_threads", "throughput", "episodes",
                  "miss_per_episode", "inval_per_episode",
                  "remote_per_episode", "latency", "unfairness",
                  "aborts", "preempts")


def result_to_doc(r: BenchResult) -> dict:
    doc = {f: getattr(r, f) for f in _SCALAR_FIELDS}
    for f in _ARRAY_FIELDS:
        a = np.asarray(getattr(r, f))
        doc[f] = {"dtype": a.dtype.str, "shape": list(a.shape),
                  "data": a.ravel().tolist()}
    return doc


def result_from_doc(doc: dict) -> BenchResult:
    kw = {f: doc[f] for f in _SCALAR_FIELDS}
    for f in _ARRAY_FIELDS:
        spec = doc[f]
        kw[f] = np.asarray(spec["data"],
                           dtype=np.dtype(spec["dtype"])).reshape(
                               spec["shape"])
    return BenchResult(**kw)


# --- the store ----------------------------------------------------------------

@dataclass
class CacheStats:
    """Per-process counters, reset never — readers take snapshots and
    diff (``registry.run_suite`` does this per suite)."""
    hits: int = 0
    misses: int = 0
    stores: int = 0

    def snapshot(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "stores": self.stores}


@dataclass
class ExperimentCache:
    """One-JSON-file-per-cell content-addressed store.

    ``enabled`` is the master switch (off = no reads, no writes);
    ``read`` gates lookups only — ``--no-cache`` sets ``read=False`` so
    everything regenerates but the store stays fresh for the next run.
    """
    root: str = ""
    enabled: bool = True
    read: bool = True
    stats: CacheStats = field(default_factory=CacheStats)

    def __post_init__(self):
        if not self.root:
            self.root = os.environ.get("REPRO_BENCH_CACHE_DIR",
                                       DEFAULT_ROOT)
        if os.environ.get("REPRO_BENCH_NO_CACHE", "") in ("1", "true"):
            self.enabled = False

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".json")

    def get(self, key: str) -> dict | None:
        if not (self.enabled and self.read):
            return None
        try:
            with open(self._path(key)) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            return None

    def put(self, key: str, doc: dict) -> None:
        if not self.enabled:
            return
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        # atomic publish: concurrent runs never see half-written entries
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f)
            os.replace(tmp, path)
        except OSError:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
        self.stats.stores += 1

    def entries(self) -> int:
        n = 0
        for _, _, files in os.walk(self.root):
            n += sum(1 for f in files if f.endswith(".json"))
        return n

    def total_bytes(self) -> int:
        total = 0
        for dirpath, _, files in os.walk(self.root):
            for f in files:
                if f.endswith(".json"):
                    with contextlib.suppress(OSError):
                        total += os.path.getsize(os.path.join(dirpath, f))
        return total

    def describe(self) -> dict:
        return {"root": os.path.abspath(self.root),
                "enabled": self.enabled, "read": self.read,
                "entries": self.entries(), "bytes": self.total_bytes(),
                **self.stats.snapshot()}


# --- process-wide instance ----------------------------------------------------

_CACHE: ExperimentCache | None = None


def get_cache() -> ExperimentCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = ExperimentCache()
    return _CACHE


def configure(*, root: str | None = None, enabled: bool | None = None,
              read: bool | None = None) -> ExperimentCache:
    """(Re)configure the process-wide cache; the CLI calls this before
    running a suite (``--cache-dir`` -> ``root``, ``--no-cache`` ->
    ``read=False``). Counters survive reconfiguration only when the
    root is unchanged."""
    global _CACHE
    cur = get_cache()
    if root is not None and root != cur.root:
        cur = ExperimentCache(root=root)
    if enabled is not None:
        cur.enabled = enabled
    if read is not None:
        cur.read = read
    _CACHE = cur
    return cur
