"""JSON result schema for the benchmark harness (``repro.bench/v1``).

Every suite run produces one *result document*:

```
{
  "schema": "repro.bench/v1",
  "suite": "paper",
  "created_unix": 1753779600.0,
  "config": {...BenchConfig...},
  "environment": {"python": "...", "jax": "...", "backend": "cpu"},
  "experiments": [<experiment>, ...]
}
```

Experiments come in four kinds, covering everything the paper's §7
evaluation reports:

* ``sweep``   — curves over an x axis (throughput-vs-threads, Figs 1-3):
                ``{"x": "threads", "y": [metric, ...], "series":
                [{"label": "mcs", "points": [{"threads": 1, ...}, ...]}]}``
* ``table``   — row/column facts (Table 1 coherence traffic):
                ``{"columns": [...], "rows": [{col: val, ...}, ...]}``
* ``scalars`` — a flat name->value mapping (Table 2 cycle, §9 fairness)
* ``hist``    — labelled histograms sharing one bin axis (bypass
                distributions): ``{"bins": [...], "series":
                [{"label": "lifo", "counts": [...]}]}``

The ``x`` axis of a sweep is whatever the suite varies — ``threads`` for
the paper figures, ``offered_load`` (requests/step) for the ``serve``
suite. The serve suite (docs/SERVING.md §6) adds three experiments, all
expressed in the existing kinds: ``serve_policy_load`` (sweep —
throughput / tail wait / prefix-hit curves per admission policy),
``serve_pool`` (table — starvation + paged-KV pool counters at the
heaviest load), and ``serve_engine_smoke`` (scalars — the model-backed
paged engine run end-to-end; full runs only, values may nest one dict of
pool counters).

The ``locks-ext`` suite (DESIGN.md §L2 extended lock zoo) likewise uses
the existing kinds: ``locksext_sweep`` (sweep — DSL-authored variants vs
paper baselines over threads), ``locksext_profile`` (table — per-lock
phase anatomy ``spec_steps``, coherence profile, and the observed
``bypass_bound`` from the admission log), and ``locksext_park`` (table —
spin_then_park throughput/latency vs the ``CostModel`` park/unpark
costs).

The ``topology`` suite (DESIGN.md §L1 machine models) also reuses the
existing kinds: ``topology_grid`` (table — every lock across the
SMP/NUMA/CCX/interleaved machine roster), ``topology_remote_scaling``
(sweep over ``nodes`` — remote misses per episode vs NUMA node count),
``topology_placement`` (table — contiguous vs interleaved pinning), and
``topology_compile`` (scalars — the SimEngine.grid one-jit-per-shape
compile accounting that CI asserts on).

The ``hostile`` suite (DESIGN.md §L1 scheduler model) is all existing
kinds too: ``hostile_grid`` (table — locks × quantum × oversubscription
with throughput-vs-dedicated ratios, preemption and abort counts),
``hostile_lhp`` (table — lock-holder-preemption penalty per lock),
``hostile_abort`` (table — the timed-wait locks' abort rate up the
hostility ladder), and ``hostile_compile`` (scalars — the scheduler-axis
compile accounting; CI asserts ``compiles_per_grid <= 1`` here as well,
pinning that schedulers batch as stacked data).

The ``gateway`` suite (docs/SERVING.md §8 fleet tier) stays inside the
same kinds: ``gateway_routers`` (table — routing policies across
fleet-level TTFT/TPOT/goodput, global cache-hit rate, load imbalance
and live tree size on the seeded multi-tenant trace),
``gateway_load`` (sweep over ``offered_load`` — the same metrics per
router as the arrival rate rises), and ``gateway_scale`` (scalars —
prefix vs random vs round_robin at 100k requests quick / 1M full,
nesting one dict per router, with the O(requests) bookkeeping bound
asserted inside the builder).

Result documents additionally carry a ``"harness"`` block (written by
``registry.run_suite``): suite wall time, fresh XLA traces paid, and
experiment-cache hit/miss/store counts for the run. The block is
advisory — ``validate_result`` ignores it — but it is what the *trend*
document aggregates.

The trend document (``repro.bench-trend/v1``, default path
``BENCH_trend.json`` next to the result) is an append-only, capped
log of harness performance: one compact entry per suite run
(``suite``, ``quick``, ``experiments``, ``wall_s``, ``xla_traces``,
``cache_hits``/``cache_misses``/``cache_stores``, ``cache_hit_rate``,
``created_unix``), so harness speed regressions are visible in review
diffs next to ``BENCH_paper.json``. ``append_trend`` is tolerant of a
missing or corrupt file (it restarts the log) — the trend is telemetry,
never a build input.

``validate_result`` is the single source of truth for well-formedness;
``save_result``/``load_result`` refuse to write or return an invalid
document, so a BENCH_*.json on disk is schema-valid by construction.
"""
from __future__ import annotations

import contextlib
import json
import sys
import time
from typing import Any

SCHEMA_VERSION = "repro.bench/v1"
TREND_SCHEMA_VERSION = "repro.bench-trend/v1"
TREND_LIMIT = 200           # entries kept per trend file (oldest dropped)
KINDS = ("sweep", "table", "scalars", "hist")


def environment_info() -> dict:
    env = {"python": sys.version.split()[0]}
    try:
        import jax
        env["jax"] = jax.__version__
        env["backend"] = jax.default_backend()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        env["jax"] = None
        env["backend"] = None
    return env


def new_result(suite: str, config: dict | None = None,
               environment: dict | None = None) -> dict:
    return {
        "schema": SCHEMA_VERSION,
        "suite": suite,
        "created_unix": time.time(),
        "config": config or {},
        "environment": (environment if environment is not None
                        else environment_info()),
        "experiments": [],
    }


# --- experiment constructors -------------------------------------------------

def sweep_experiment(name: str, title: str, x: str, series: list,
                     y: list | None = None, meta: dict | None = None) -> dict:
    if y is None:
        keys: list = []
        for s in series:
            for p in s.get("points", []):
                for k in p:
                    if k != x and k not in keys:
                        keys.append(k)
        y = keys
    return {"name": name, "kind": "sweep", "title": title, "x": x, "y": y,
            "series": series, "meta": meta or {}}


def table_experiment(name: str, title: str, columns: list, rows: list,
                     meta: dict | None = None) -> dict:
    return {"name": name, "kind": "table", "title": title,
            "columns": list(columns), "rows": rows, "meta": meta or {}}


def scalars_experiment(name: str, title: str, values: dict,
                       meta: dict | None = None) -> dict:
    return {"name": name, "kind": "scalars", "title": title,
            "values": values, "meta": meta or {}}


def hist_experiment(name: str, title: str, bins: list, series: list,
                    meta: dict | None = None) -> dict:
    return {"name": name, "kind": "hist", "title": title, "bins": list(bins),
            "series": series, "meta": meta or {}}


# --- validation --------------------------------------------------------------

def _err(errors: list, where: str, msg: str) -> None:
    errors.append(f"{where}: {msg}")


def _check_series(errors: list, where: str, exp: dict) -> None:
    x = exp.get("x")
    if not isinstance(x, str):
        _err(errors, where, "sweep needs a string 'x' axis name")
        return
    series = exp.get("series")
    if not isinstance(series, list) or not series:
        _err(errors, where, "sweep needs a non-empty 'series' list")
        return
    for i, s in enumerate(series):
        w = f"{where}.series[{i}]"
        if not isinstance(s, dict) or not isinstance(s.get("label"), str):
            _err(errors, w, "series needs a string 'label'")
            continue
        pts = s.get("points")
        if not isinstance(pts, list) or not pts:
            _err(errors, w, "series needs a non-empty 'points' list")
            continue
        for j, p in enumerate(pts):
            if not isinstance(p, dict) or x not in p:
                _err(errors, f"{w}.points[{j}]",
                     f"point must be a dict containing the x key {x!r}")
            elif not isinstance(p[x], (int, float)):
                _err(errors, f"{w}.points[{j}]", f"x value {p[x]!r} not numeric")


def _check_experiment(errors: list, i: int, exp: Any) -> None:
    where = f"experiments[{i}]"
    if not isinstance(exp, dict):
        _err(errors, where, "experiment must be a dict")
        return
    name = exp.get("name")
    if not isinstance(name, str) or not name:
        _err(errors, where, "experiment needs a non-empty string 'name'")
    kind = exp.get("kind")
    if kind not in KINDS:
        _err(errors, where, f"kind {kind!r} not in {KINDS}")
        return
    if not isinstance(exp.get("title"), str):
        _err(errors, where, "experiment needs a string 'title'")
    if kind == "sweep":
        _check_series(errors, where, exp)
    elif kind == "table":
        cols = exp.get("columns")
        if not isinstance(cols, list) or not all(
                isinstance(c, str) for c in cols):
            _err(errors, where, "table needs a list[str] 'columns'")
        if not isinstance(exp.get("rows"), list):
            _err(errors, where, "table needs a list 'rows'")
        else:
            for j, r in enumerate(exp["rows"]):
                if not isinstance(r, dict):
                    _err(errors, f"{where}.rows[{j}]", "row must be a dict")
    elif kind == "scalars":
        if not isinstance(exp.get("values"), dict):
            _err(errors, where, "scalars needs a dict 'values'")
    elif kind == "hist":
        bins = exp.get("bins")
        if not isinstance(bins, list) or not bins:
            _err(errors, where, "hist needs a non-empty 'bins' list")
            return
        for j, s in enumerate(exp.get("series") or []):
            w = f"{where}.series[{j}]"
            if not isinstance(s, dict) or not isinstance(s.get("label"), str):
                _err(errors, w, "hist series needs a string 'label'")
            elif (not isinstance(s.get("counts"), list)
                  or len(s["counts"]) != len(bins)):
                _err(errors, w, "hist series 'counts' must match bins length")


def validate_result(doc: Any) -> list:
    """Return a list of problems (empty == schema-valid)."""
    errors: list = []
    if not isinstance(doc, dict):
        return ["document must be a dict"]
    if doc.get("schema") != SCHEMA_VERSION:
        _err(errors, "schema", f"expected {SCHEMA_VERSION!r}, "
             f"got {doc.get('schema')!r}")
    if not isinstance(doc.get("suite"), str) or not doc.get("suite"):
        _err(errors, "suite", "needs a non-empty string suite name")
    exps = doc.get("experiments")
    if not isinstance(exps, list):
        _err(errors, "experiments", "must be a list")
        exps = []
    names = [e.get("name") for e in exps if isinstance(e, dict)]
    dupes = {n for n in names if names.count(n) > 1}
    if dupes:
        _err(errors, "experiments", f"duplicate experiment names: {sorted(dupes)}")
    for i, exp in enumerate(exps):
        _check_experiment(errors, i, exp)
    try:
        json.dumps(doc)
    except (TypeError, ValueError) as e:
        _err(errors, "document", f"not JSON-serializable: {e}")
    return errors


def save_result(doc: dict, path: str) -> None:
    errors = validate_result(doc)
    if errors:
        raise ValueError("refusing to write invalid result:\n  "
                         + "\n  ".join(errors))
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")


# --- the trend log -----------------------------------------------------------

def trend_entry(doc: dict) -> dict:
    """Compact trend-log entry from a result document's harness block."""
    h = doc.get("harness") or {}
    return {
        "suite": doc.get("suite"),
        "quick": bool((doc.get("config") or {}).get("quick")),
        "experiments": len(doc.get("experiments") or []),
        "wall_s": h.get("wall_s"),
        "xla_traces": h.get("xla_traces"),
        "cache_hits": h.get("cache_hits"),
        "cache_misses": h.get("cache_misses"),
        "cache_stores": h.get("cache_stores"),
        "cache_hit_rate": h.get("cache_hit_rate"),
        "created_unix": doc.get("created_unix"),
    }


def load_trend(path: str) -> dict:
    """The trend document at ``path``; a fresh empty one if the file is
    missing or unreadable (the trend is telemetry, never a build
    input)."""
    with contextlib.suppress(OSError, json.JSONDecodeError):
        with open(path) as f:
            doc = json.load(f)
        if (isinstance(doc, dict)
                and doc.get("schema") == TREND_SCHEMA_VERSION
                and isinstance(doc.get("entries"), list)):
            return doc
    return {"schema": TREND_SCHEMA_VERSION, "entries": []}


def append_trend(path: str, entry: dict) -> dict:
    """Append one run's entry to the trend log at ``path`` (capped at
    ``TREND_LIMIT`` entries) and return the updated document."""
    doc = load_trend(path)
    doc["entries"] = (doc["entries"] + [entry])[-TREND_LIMIT:]
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def load_result(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    errors = validate_result(doc)
    if errors:
        raise ValueError(f"{path} is not a valid {SCHEMA_VERSION} document:"
                         "\n  " + "\n  ".join(errors))
    return doc
