"""Serving launcher: ``PYTHONPATH=src python -m repro.launch.serve
--arch granite-3-2b --smoke --requests 8``."""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--policy", default="reciprocating")
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.models import model as M_
    from repro.serve.engine import GenRequest, InferenceEngine

    cfg = smoke_config(get_config(args.arch))
    params = M_.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, policy=args.policy)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        toks = rng.integers(1, min(cfg.vocab_size, 97),
                            rng.integers(4, 17), dtype=np.int32)
        eng.submit(GenRequest(rid=i, tokens=toks, max_new=8))
    done = eng.run()
    for r in done:
        print(f"req {r.rid}: prompt_len={len(r.tokens)} out={r.out}")
    print(f"[serve] completed {len(done)} requests "
          f"(policy={args.policy})")


if __name__ == "__main__":
    main()
