"""Serving launcher: ``PYTHONPATH=src python -m repro.launch.serve
--arch granite-3-2b --smoke --requests 8``.

Runs the continuous-batching engine (docs/SERVING.md): paged KV on the
supported families, dense slot fallback elsewhere; per-step admission
under the chosen policy and per-request early exit either way.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--policy", default="reciprocating")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.models import model as M_
    from repro.serve.engine import GenRequest, InferenceEngine

    cfg = smoke_config(get_config(args.arch))
    params = M_.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, policy=args.policy,
                          max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        toks = rng.integers(1, min(cfg.vocab_size, 97),
                            rng.integers(4, 17), dtype=np.int32)
        eng.submit(GenRequest(rid=i, tokens=toks,
                              max_new=int(rng.integers(1, args.max_new + 1))))
    done = eng.run()
    for r in done:
        print(f"req {r.rid}: prompt_len={len(r.tokens)} "
              f"admitted@{r.admitted:.0f} finished@{r.finished:.0f} "
              f"out={r.out}")
    c = eng.counters
    print(f"[serve] completed {len(done)} requests "
          f"(policy={args.policy}, paged={eng.paged}, "
          f"{int(eng.core.time)} steps, {c.slot_steps} slot-steps)")


if __name__ == "__main__":
    main()
