"""jit-able train / serve steps + their input specs and shardings.

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell, and the ones the real train/serve loops execute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decode as D_
from repro.models import model as M_
from repro.sharding.ctx import MeshCtx
from repro.sharding.rules import shardings_for
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state

F32 = jnp.float32


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def batch_struct(cfg: ModelConfig, shape: ShapeConfig, kind: str | None = None):
    """Abstract input batch for the given shape. kind defaults to shape.kind."""
    kind = kind or shape.kind
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct

    if kind == "decode":
        return {"tokens": sd((B,), i32),
                "cache": D_.init_cache(cfg, B, S, abstract=True)}

    batch = {}
    s_text = S - cfg.n_patches if cfg.n_patches else S
    batch["tokens"] = sd((B, s_text), i32)
    if cfg.n_patches:
        batch["patches"] = sd((B, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        batch["frames"] = sd((B, cfg.enc_frames, cfg.d_model), cfg.dtype)
    if kind == "train":
        batch["labels"] = sd((B, s_text), i32)
        batch["mask"] = sd((B, s_text), F32)
    return batch


def batch_pspecs(cfg: ModelConfig, ctx: MeshCtx, kind: str,
                 global_batch: int = 0):
    ba = ctx.batch_axes
    if global_batch and global_batch % ctx.data_size != 0:
        ba = None       # tiny batches (e.g. long_500k B=1) stay replicated
    if kind == "decode":
        return {"tokens": P(ba), "cache": D_.cache_pspecs(cfg, ctx, ba)}
    specs = {"tokens": P(ba, None)}
    if cfg.n_patches:
        specs["patches"] = P(ba, None, None)
    if cfg.is_encoder_decoder:
        specs["frames"] = P(ba, None, None)
    if kind == "train":
        specs["labels"] = P(ba, None)
        specs["mask"] = P(ba, None)
    return specs


def to_shardings(pspec_tree, ctx: MeshCtx):
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def train_state_struct(cfg: ModelConfig, ctx: MeshCtx):
    params = M_.abstract_params(cfg, ctx.model_size)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, F32)
    return {"params": params,
            "opt": {"mu": jax.tree.map(f32, params),
                    "nu": jax.tree.map(f32, params),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def train_state_shardings(cfg: ModelConfig, ctx: MeshCtx):
    ps = shardings_for(M_.logical_axes(cfg, ctx.model_size), ctx,
                       M_.abstract_params(cfg, ctx.model_size))
    return {"params": ps,
            "opt": {"mu": ps, "nu": ps,
                    "step": NamedSharding(ctx.mesh, P())}}


def init_train_state(cfg: ModelConfig, ctx: MeshCtx, key,
                     oc: OptConfig = OptConfig()):  # noqa: B008
    params = M_.init_params(cfg, key, ctx.model_size)
    return {"params": params,
            "opt": init_opt_state(params, oc.master_fp32)}


def make_train_step(cfg: ModelConfig, ctx: MeshCtx,
                    oc: OptConfig = OptConfig()):  # noqa: B008
    def train_step(state, batch):
        def lf(params):
            return M_.loss_fn(params, batch, cfg, ctx)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            state["params"])
        new_p, new_opt, gn = adamw_update(grads, state["opt"],
                                          state["params"], oc)
        metrics = dict(metrics, loss=loss, grad_norm=gn)
        return {"params": new_p, "opt": new_opt}, metrics
    return train_step


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, ctx: MeshCtx):
    def prefill(params, batch):
        return D_.prefill_step(params, batch, cfg, ctx)
    return prefill


def make_decode_step(cfg: ModelConfig, ctx: MeshCtx):
    def decode(params, batch):
        return D_.decode_step(params, batch["cache"], batch["tokens"],
                              cfg, ctx)
    return decode


def step_for_kind(cfg: ModelConfig, ctx: MeshCtx, kind: str):
    if kind == "train":
        return make_train_step(cfg, ctx)
    if kind == "prefill":
        return make_prefill_step(cfg, ctx)
    return make_decode_step(cfg, ctx)
