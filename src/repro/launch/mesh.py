"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import; smoke tests and benchmarks see the real single device.
"""
from __future__ import annotations

from repro.sharding.compat import make_mesh
from repro.sharding.ctx import MeshCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_ctx(mesh) -> MeshCtx:
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return MeshCtx(mesh=mesh, batch_axes=ba, model_axis="model")


def make_smoke_mesh(data: int = 1, model: int = 1):
    return make_mesh((data, model), ("data", "model"))
