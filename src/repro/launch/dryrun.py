"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module entry point. The first two lines below force 512
host platform devices BEFORE any jax import so ``jax.make_mesh`` can build
the production meshes. Never set this globally.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out benchmarks/artifacts
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.launch.mesh import make_ctx, make_production_mesh           # noqa: E402
from repro.launch import steps as ST                                   # noqa: E402
from repro.models import model as M_                                   # noqa: E402
from repro.sharding.rules import shardings_for                         # noqa: E402

# ---------------------------------------------------------------------------
# v5e hardware constants (roofline)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\d]+\[[^\]]*\][^\s]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\w-]*\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device bytes by collective kind, parsed from partitioned HLO."""
    out: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        ty, kind = m.group(1), m.group(2)
        b = _shape_bytes(ty)
        d = out.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


def collective_seconds(stats: dict) -> float:
    t = 0.0
    for kind, d in stats.items():
        factor = 2.0 if kind == "all-reduce" else 1.0   # ring AR moves ~2x
        t += factor * d["bytes"] / ICI_BW
    return t


def model_flops(cfg, shape) -> float:
    """6*N*D (train), 2*N*D (prefill), 2*N_active*B (decode) conventions."""
    n_active = M_.count_active_params(cfg, include_embed=False)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_encoder_decoder:
            tokens += shape.global_batch * cfg.enc_frames
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------
def shallow_depths(cfg) -> tuple[int, int]:
    """Two reduced depths whose per-layer delta extrapolates exactly (they
    respect the arch's layer-pattern period)."""
    if cfg.shared_attn_every:                     # zamba2: cadence 6
        return 6, 12
    if cfg.first_dense_layers:                    # deepseek: 1 dense + k moe
        return cfg.first_dense_layers + 2, cfg.first_dense_layers + 4
    return 2, 4


def _lower_compile(cfg, shape, ctx, kind):
    step = ST.step_for_kind(cfg, ctx, kind)
    batch = ST.batch_struct(cfg, shape)
    batch_sh = ST.to_shardings(
        ST.batch_pspecs(cfg, ctx, kind, shape.global_batch), ctx)
    if kind == "train":
        state = ST.train_state_struct(cfg, ctx)
        state_sh = ST.train_state_shardings(cfg, ctx)
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,)).lower(state, batch)
    else:
        params = M_.abstract_params(cfg, ctx.model_size)
        # serve path: model-only sharding for dense weights (see rules())
        params_sh = shardings_for(M_.logical_axes(cfg, ctx.model_size), ctx,
                                  params, serve=True)
        out_sh = (None, batch_sh["cache"]) if kind == "decode" else None
        # decode: donate the batch (KV cache) so in-place cache updates
        # alias instead of copying (EXPERIMENTS §Perf granite cell, iter 3)
        donate = (1,) if kind == "decode" else ()
        lowered = jax.jit(step, in_shardings=(params_sh, batch_sh),
                          out_shardings=out_sh,
                          donate_argnums=donate).lower(params, batch)
    return lowered.compile()


def _rates(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one entry per program
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": collective_stats(compiled.as_text())}


def _extrapolate(r1, r2, L1, L2, L) -> dict:
    """Linear in layer count: exact for homogeneous layer stacks."""
    def lin(a, b):
        return b + (b - a) * (L - L2) / (L2 - L1)
    out = {"flops": lin(r1["flops"], r2["flops"]),
           "bytes": lin(r1["bytes"], r2["bytes"]), "coll": {}}
    kinds = set(r1["coll"]) | set(r2["coll"])
    for k in kinds:
        c1 = r1["coll"].get(k, {"count": 0, "bytes": 0})
        c2 = r2["coll"].get(k, {"count": 0, "bytes": 0})
        out["coll"][k] = {
            "count": int(round(lin(c1["count"], c2["count"]))),
            "bytes": lin(c1["bytes"], c2["bytes"]),
        }
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    """Two-phase dry-run (see EXPERIMENTS.md methodology):

    A) full config with scanned layers: proves the cell lowers+compiles on
       the production mesh and yields the honest per-device memory figure.
    B) two shallow *unrolled* configs (inner loops unrolled too): XLA's
       cost analysis counts loop bodies once, so rates are taken from the
       unrolled graphs and extrapolated linearly in depth — exact for the
       homogeneous layer stacks used here.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ctx = make_ctx(mesh)
    n_chips = mesh.size

    with mesh:
        # -- phase A: full config, scan, memory truth -----------------------
        t0 = time.time()
        comp_full = _lower_compile(cfg, shape, ctx, shape.kind)
        t_full = time.time() - t0
        ma = comp_full.memory_analysis()

        # -- phase B: shallow unrolled rates --------------------------------
        L1, L2 = shallow_depths(cfg)
        t0 = time.time()
        rates = []
        for Ls in (L1, L2):
            kw = {"n_layers": Ls, "scan_layers": False}
            if cfg.is_encoder_decoder:
                kw["n_enc_layers"] = Ls
            c = cfg.replace(**kw)
            rates.append(_rates(_lower_compile(c, shape, ctx, shape.kind)))
        t_shallow = time.time() - t0
        R = _extrapolate(rates[0], rates[1], L1, L2, cfg.n_layers)

    flops_dev, bytes_dev, coll = R["flops"], R["bytes"], R["coll"]
    terms = {"compute": flops_dev / PEAK_FLOPS,
             "memory": bytes_dev / HBM_BW,
             "collective": collective_seconds(coll)}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_total = flops_dev * n_chips

    mem = {k: getattr(ma, k) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")}
    peak_bytes = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
                  + mem["output_size_in_bytes"] - mem["alias_size_in_bytes"])

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "chips": n_chips,
        "compile_s": round(t_full, 2), "shallow_s": round(t_shallow, 2),
        "memory": mem, "peak_bytes_per_device": peak_bytes,
        "fits_16gb": bool(peak_bytes < 16e9),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collectives": coll,
        "roofline_seconds": terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_flops_total,
        "useful_flop_ratio": (mf / hlo_flops_total) if hlo_flops_total else 0,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = os.path.join(
                    args.out, f"dryrun_{arch}_{shape}_{mesh_kind}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {path}")
                    continue
                try:
                    res = run_cell(arch, shape, mesh_kind)
                except Exception as e:       # a failure here is a bug
                    failures += 1
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                msg = res["status"]
                if res["status"] == "ok":
                    msg += (f" compile={res['compile_s']}s"
                            f" peak={res['peak_bytes_per_device']/1e9:.2f}GB"
                            f" dom={res['dominant']}")
                print(f"[{arch} x {shape} x {mesh_kind}] {msg}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
