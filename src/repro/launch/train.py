"""Training launcher: ``PYTHONPATH=src python -m repro.launch.train
--arch granite-3-2b --smoke --steps 100``.

``--smoke`` trains the reduced config on the local device (CPU-runnable
end-to-end driver); without it, the full config trains on the production
mesh (requires real hardware; the dry-run proves the program compiles).
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.sharding.ctx import trivial_ctx
    from repro.train.train_loop import RunConfig, train

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
        ctx = trivial_ctx()
    else:
        from repro.launch.mesh import make_ctx, make_production_mesh
        ctx = make_ctx(make_production_mesh(multi_pod=args.multi_pod))

    out = train(cfg, ctx, RunConfig(steps=args.steps,
                                    ckpt_dir=args.ckpt_dir))
    print(f"[train] done; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
