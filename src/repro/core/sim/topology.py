"""Hierarchical machine topologies: first-class cost models for the
lock simulator.

The paper's coherence arguments — O(1) handoff bus transactions, the
"Maximum Remote Misses" family, NUMA sensitivity — are statements about
*machine topology*, not about a single local/remote cost pair. A
:class:`Topology` describes a machine as a balanced tree of domains
(SMT siblings / cores / CCX clusters / sockets / the whole box), each
level with its own line-transfer cost, and lowers to the one interface
the machine engine consumes: a **thread x thread cost matrix**
(:class:`~repro.core.sim.machine.LoweredCost`).

Model
-----
* ``levels`` runs innermost -> outermost. ``Level(name, size, cost)``
  groups ``size`` units of the previous level (level 0 groups hardware
  threads); ``cost`` is the cycles a coherence miss pays when the
  requesting thread and the line's home first share a domain at this
  level (their lowest common ancestor).
* ``Level(remote=True)`` marks a *NUMA boundary*: a miss resolving at or
  above it is counted as a remote miss (Table 1's
  ``remote_per_episode``).
* ``placement`` maps thread slot -> leaf. The default is the identity
  (contiguous packing, exactly the flat ``CostModel`` convention);
  :meth:`Topology.interleave` round-robins threads across the outermost
  domains instead — the classic "scatter" pinning policy.
* Per-word homing stays thread-indexed: ``Program.home[w] == t`` homes
  word ``w`` with thread ``t`` (the paper's sequestered wait elements),
  and ``-1`` homes it with thread 0 (lock words, node 0). Placement is
  applied when the matrix is built, so the same compiled program runs
  unchanged on every topology — ``compile.py`` does not re-lower.

Because the lowered form is plain arrays, a *grid of topologies* is just
a stacked batch of ``(T, T)`` matrices: ``SimEngine.grid`` vmaps one XLA
program over them, so an SMP box, a 4-node NUMA box and a clustered-CCX
part share a single compile (one jit per shape, never per topology).

Presets: :func:`smp`, :func:`numa`, :func:`ccx` factories plus the named
real-machine profiles in :data:`PRESETS` (``python -m repro.bench list
--topologies`` prints the catalogue).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field, replace

import numpy as np

__all__ = [
    "Level", "Topology", "smp", "numa", "ccx", "PRESETS", "resolve",
    "catalogue",
]


@dataclass(frozen=True)
class Level:
    """One tier of the domain tree.

    ``size``   — units of the previous level grouped into one domain
                 (level 0 groups hardware threads).
    ``cost``   — line-transfer cycles when this level is the lowest
                 common ancestor of requester and home.
    ``remote`` — crossing into this level is a NUMA-remote transfer.
    """
    name: str
    size: int
    cost: int
    remote: bool = False


@dataclass(frozen=True)
class Topology:
    """A machine as a balanced tree of domains (innermost -> outermost).

    ``hit`` / ``park_cost`` / ``unpark_cost`` / ``resched_cost``
    complete the cost model (same semantics as the flat ``CostModel``
    fields). ``placement`` maps thread slot -> leaf; ``()`` is the
    identity."""
    name: str
    levels: tuple = ()
    hit: int = 1
    park_cost: int = 25
    unpark_cost: int = 75
    resched_cost: int = 150
    placement: tuple = field(default=())

    def __post_init__(self):
        if not self.levels:
            raise ValueError(f"topology {self.name!r} declares no levels")
        for lv in self.levels:
            if lv.size < 1:
                raise ValueError(f"{self.name}: level {lv.name!r} has "
                                 f"size {lv.size} < 1")

    # -- structure -----------------------------------------------------------
    @property
    def n_leaves(self) -> int:
        return int(np.prod([lv.size for lv in self.levels]))

    def capacities(self) -> list:
        """Leaves per domain at each level (cumulative level sizes)."""
        caps, c = [], 1
        for lv in self.levels:
            c *= lv.size
            caps.append(c)
        return caps

    def leaves(self, n_threads: int) -> np.ndarray:
        """Thread slot -> leaf under the placement (identity default)."""
        if n_threads > self.n_leaves:
            raise ValueError(
                f"topology {self.name!r} has {self.n_leaves} hardware "
                f"threads; cannot place {n_threads}")
        if self.placement:
            if len(self.placement) < n_threads:
                raise ValueError(
                    f"{self.name}: placement covers "
                    f"{len(self.placement)} threads < {n_threads}")
            return np.asarray(self.placement[:n_threads], np.int64)
        return np.arange(n_threads, dtype=np.int64)

    def interleave(self) -> "Topology":
        """Round-robin placement across the outermost domains (scatter
        pinning): thread i lands in domain ``i % n_domains``."""
        per = self.capacities()[-2] if len(self.levels) > 1 else 1
        n_out = self.levels[-1].size if len(self.levels) > 1 \
            else self.n_leaves
        order = tuple(int((i % n_out) * per + i // n_out)
                      for i in range(self.n_leaves))
        return replace(self, name=f"{self.name}+interleave",
                       placement=order)

    # -- lowering ------------------------------------------------------------
    def _lca_level(self, n_threads: int) -> np.ndarray:
        """(T, T) index of the lowest level where each thread pair shares
        a domain (0 = innermost)."""
        leaf = self.leaves(n_threads)
        lca = np.full((n_threads, n_threads), len(self.levels) - 1,
                      np.int64)
        for d, cap in reversed(list(enumerate(self.capacities()))):
            dom = leaf // cap
            lca = np.where(dom[:, None] == dom[None, :], d, lca)
        return lca

    def cost_matrix(self, n_threads: int) -> np.ndarray:
        """(T, T) int32: miss cycles for requester row, home-thread col."""
        costs = np.asarray([lv.cost for lv in self.levels], np.int32)
        return costs[self._lca_level(n_threads)]

    def remote_matrix(self, n_threads: int) -> np.ndarray:
        """(T, T) bool: pairs whose transfers cross a NUMA boundary."""
        rem = np.asarray([lv.remote for lv in self.levels], bool)
        return rem[self._lca_level(n_threads)]

    def lower(self, n_threads: int):
        """Lower to the machine's :class:`LoweredCost` (jnp arrays)."""
        import jax.numpy as jnp

        from repro.core.sim.machine import LoweredCost
        return LoweredCost(
            hit=jnp.int32(self.hit),
            miss=jnp.asarray(self.cost_matrix(n_threads), jnp.int32),
            remote=jnp.asarray(self.remote_matrix(n_threads), bool),
            park=jnp.int32(self.park_cost),
            unpark=jnp.int32(self.unpark_cost),
            resched=jnp.int32(self.resched_cost))

    # -- description ---------------------------------------------------------
    def describe(self) -> dict:
        return {
            "name": self.name,
            "threads": self.n_leaves,
            "levels": [(lv.name, lv.size, lv.cost, lv.remote)
                       for lv in self.levels],
            "placement": "interleaved" if self.placement else "contiguous",
        }

    def summary(self) -> str:
        tiers = " > ".join(
            f"{lv.name}[{lv.size}]@{lv.cost}{'*' if lv.remote else ''}"
            for lv in reversed(self.levels))
        return f"{self.n_leaves}t  {tiers}"


# --- factories ---------------------------------------------------------------

def smp(n_threads: int, miss: int = 40, hit: int = 1) -> Topology:
    """Degenerate single-level topology: one symmetric domain, every miss
    local. Bit-identical to the flat ``CostModel(n_nodes=1)`` path (the
    migration oracle in tests/test_topology.py pins this)."""
    return Topology(f"smp{n_threads}",
                    levels=(Level("node", n_threads, miss),), hit=hit)


def numa(nodes: int, per_node: int = 8, local: int = 40,
         remote: int = 100, hit: int = 1) -> Topology:
    """Classic flat NUMA: ``nodes`` sockets, uniform remote cost. With
    contiguous placement this matches ``CostModel(n_nodes=nodes)`` when
    ``T == nodes * per_node``."""
    return Topology(
        f"numa{nodes}x{per_node}",
        levels=(Level("node", per_node, local),
                Level("machine", nodes, remote, remote=True)), hit=hit)


def ccx(sockets: int = 2, ccx_per_socket: int = 2, per_ccx: int = 4,
        ccx_cost: int = 25, socket_cost: int = 60,
        cross_cost: int = 140, hit: int = 1) -> Topology:
    """Clustered-CCX part (chiplet CPUs): cheap intra-CCX transfers, a
    mid-cost hop between CCX dies on one socket, and an expensive
    cross-socket (NUMA-remote) hop."""
    return Topology(
        f"ccx{sockets}x{ccx_per_socket}x{per_ccx}",
        levels=(Level("ccx", per_ccx, ccx_cost),
                Level("socket", ccx_per_socket, socket_cost),
                Level("machine", sockets, cross_cost, remote=True)),
        hit=hit)


#: Named real-machine profiles (shapes and relative costs modelled after
#: published latency matrices; cycle values are in the simulator's units,
#: where a flat local miss is 40).
PRESETS: dict = {
    # 2-socket chiplet server: 8 CCDs/socket, 4 threads/CCX slice.
    "epyc-2s": Topology(
        "epyc-2s",
        levels=(Level("ccx", 4, 25),
                Level("socket", 8, 60),
                Level("machine", 2, 140, remote=True))),
    # 4-socket monolithic-mesh server: SMT pairs, one mesh per socket,
    # UPI hops between sockets.
    "xeon-4s": Topology(
        "xeon-4s",
        levels=(Level("smt", 2, 8),
                Level("socket", 8, 45),
                Level("machine", 4, 110, remote=True))),
    # 2-die UMA-ish desktop part: fast core clusters, moderate die hop.
    "m2-ultra": Topology(
        "m2-ultra",
        levels=(Level("cluster", 4, 20),
                Level("die", 3, 55),
                Level("machine", 2, 90, remote=True))),
}


def resolve(t) -> Topology:
    """Accept a ``Topology``, a preset name, or ``smp:N`` / ``numa:KxP``
    / ``ccx[:SxCxP]`` shorthand; return a ``Topology``."""
    if isinstance(t, Topology):
        return t
    if not isinstance(t, str):
        raise TypeError(f"not a topology: {t!r}")
    if t in PRESETS:
        return PRESETS[t]
    kind, _, arg = t.partition(":")
    with contextlib.suppress(ValueError):
        if kind == "smp":
            return smp(int(arg or 8))
        if kind == "numa":
            k, _, p = arg.partition("x")
            return numa(int(k or 2), int(p or 8))
        if kind == "ccx":
            if not arg:
                return ccx()
            s, c, p = arg.split("x")
            return ccx(int(s), int(c), int(p))
    raise KeyError(
        f"unknown topology {t!r}; presets: {sorted(PRESETS)}; shorthand: "
        "smp:N, numa:KxP, ccx[:SxCxP]")


def catalogue() -> list:
    """Rows for ``python -m repro.bench list --topologies``: the named
    profiles plus one canonical instance of each factory."""
    rows = [("smp:N", smp(8)), ("numa:KxP", numa(2, 4)), ("ccx", ccx())]
    rows += sorted(PRESETS.items())
    return [(name, t.summary()) for name, t in rows]
