"""Hostile-OS scheduler models: preemption and oversubscription as
first-class, traced simulation inputs.

The paper's constant-time doorway and bounded-bypass guarantees are most
interesting exactly when the OS is adversarial — the admitted thread can
be descheduled mid-critical-section (lock-holder preemption), and waiters
can outnumber cores (oversubscription, the regime Fissile-style
spin-then-park exists for). A :class:`Scheduler` describes that OS and
lowers to the one interface the machine stepper consumes: four scalar
traced values (:class:`~repro.core.sim.machine.LoweredSched`).

Model
-----
* ``quantum``     — cycles a thread may burn on-core before the timer
                    tick deschedules it (``None``: run-to-completion,
                    never preempt).
* ``oversub``     — threads-per-core ratio; ``cores = max(1,
                    ceil(T / oversub))`` at lower time, so one Scheduler
                    value is meaningful across a whole thread-count
                    sweep. A preempted thread waits out the other
                    runnables' quanta on its core before re-dispatch.
* ``lhp_quantum`` — optional tighter slice applied *while the thread
                    holds the lock* (admission through NCS return): the
                    lock-holder-preemption bias that makes the holder
                    vanish mid-CS with high probability.
* ``jitter``      — seeded per-slice budget jitter span in cycles; the
                    per-thread xorshift stream makes preemption points
                    deterministic per seed but uncorrelated across
                    threads (random preemption schedules for the
                    property harness).

Like ``LoweredCost``, the lowered form is pure data, not shape: a grid
of schedulers is a stacked batch of four scalars vmapped through one XLA
program — ``SimEngine.grid(schedulers=[...])`` adds the axis without a
single extra jit trace (CI pins ``compiles_per_grid <= 1``).

The degenerate scheduler (``dedicated``: no quantum, oversub 1) lowers
to (INF, INF, T, 0), which collapses every scheduler term in the stepper
to the schedulerless arithmetic — bit-identical ``MachineState``s, the
differential invariant tests/test_hostile.py pins for every lock.

Presets in :data:`PRESETS` (``python -m repro.bench list --schedulers``
prints the catalogue); :func:`resolve` also accepts ``fair:QxR`` /
``lhp:QxLxR`` shorthand.
"""
from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass

__all__ = ["Scheduler", "PRESETS", "resolve", "catalogue"]


@dataclass(frozen=True)
class Scheduler:
    """An OS scheduler as four numbers (see module docstring).

    ``quantum`` / ``lhp_quantum`` are cycles (``None``: never fires);
    ``oversub`` is the threads:cores ratio (1.0 = dedicated cores);
    ``jitter`` widens each slice budget by a seeded 0..jitter draw."""
    name: str
    quantum: int | None = None
    oversub: float = 1.0
    lhp_quantum: int | None = None
    jitter: int = 0

    def __post_init__(self):
        if self.quantum is not None and self.quantum < 1:
            raise ValueError(f"{self.name}: quantum {self.quantum} < 1")
        if self.lhp_quantum is not None and self.lhp_quantum < 1:
            raise ValueError(
                f"{self.name}: lhp_quantum {self.lhp_quantum} < 1")
        if self.oversub < 1.0:
            raise ValueError(f"{self.name}: oversub {self.oversub} < 1")
        if self.jitter < 0:
            raise ValueError(f"{self.name}: jitter {self.jitter} < 0")
        if self.lhp_quantum is not None and self.quantum is None:
            raise ValueError(
                f"{self.name}: lhp_quantum without a base quantum")

    def cores(self, n_threads: int) -> int:
        """Physical cores backing ``n_threads`` software threads."""
        return max(1, math.ceil(n_threads / self.oversub))

    # -- lowering ------------------------------------------------------------
    def lower(self, n_threads: int):
        """Lower to the machine's :class:`LoweredSched` (scalar jnp
        data — stackable across a grid axis under one jit)."""
        import jax.numpy as jnp

        from repro.core.sim.machine import INF, LoweredSched
        q = INF if self.quantum is None else jnp.int32(self.quantum)
        lq = q if self.lhp_quantum is None else jnp.int32(self.lhp_quantum)
        return LoweredSched(
            quantum=jnp.asarray(q, jnp.int32),
            lhp_quantum=jnp.asarray(lq, jnp.int32),
            cores=jnp.int32(self.cores(n_threads)),
            jitter=jnp.int32(self.jitter))

    # -- description ---------------------------------------------------------
    def describe(self) -> dict:
        return {
            "name": self.name,
            "quantum": self.quantum,
            "oversub": self.oversub,
            "lhp_quantum": self.lhp_quantum,
            "jitter": self.jitter,
        }

    def summary(self) -> str:
        q = "run-to-completion" if self.quantum is None \
            else f"q={self.quantum}"
        bits = [q, f"oversub={self.oversub:g}x"]
        if self.lhp_quantum is not None:
            bits.append(f"lhp_q={self.lhp_quantum}")
        if self.jitter:
            bits.append(f"jitter={self.jitter}")
        return "  ".join(bits)


#: Named scheduler profiles. Quanta are sized against the simulator's
#: cost units (hit 1, local miss 40, remote miss ~100, one contended
#: episode a few hundred cycles): q=2500 deschedules every handful of
#: episodes; the holder-bane lhp slice of 600 reliably fires *inside*
#: the lock-held window.
PRESETS: dict = {
    # The classic benchmarking setup: pinned, dedicated, never preempted.
    "dedicated": Scheduler("dedicated"),
    # Timeslicing CFS-style fair scheduler at 2x / 4x oversubscription.
    "fair-2x": Scheduler("fair-2x", quantum=2500, oversub=2.0, jitter=500),
    "fair-4x": Scheduler("fair-4x", quantum=2500, oversub=4.0, jitter=500),
    # Adversarial lock-holder preemption: a tight slice while holding.
    "holder-bane": Scheduler("holder-bane", quantum=2500, oversub=2.0,
                             lhp_quantum=600, jitter=500),
}


def resolve(s) -> Scheduler:
    """Accept a ``Scheduler``, ``None`` (dedicated), a preset name, or
    ``fair:QxR`` / ``lhp:QxLxR`` shorthand; return a ``Scheduler``."""
    if s is None:
        return PRESETS["dedicated"]
    if isinstance(s, Scheduler):
        return s
    if not isinstance(s, str):
        raise TypeError(f"not a scheduler: {s!r}")
    if s in PRESETS:
        return PRESETS[s]
    kind, _, arg = s.partition(":")
    with contextlib.suppress(ValueError):
        if kind == "fair":
            q, _, r = arg.partition("x")
            return Scheduler(s, quantum=int(q or 2500),
                             oversub=float(r or 2.0))
        if kind == "lhp":
            q, lq, r = arg.split("x")
            return Scheduler(s, quantum=int(q), lhp_quantum=int(lq),
                             oversub=float(r))
    raise KeyError(
        f"unknown scheduler {s!r}; presets: {sorted(PRESETS)}; "
        "shorthand: fair:QxR, lhp:QxLxR")


def catalogue() -> list:
    """Rows for ``python -m repro.bench list --schedulers``: the named
    profiles plus the shorthand forms."""
    rows = sorted(PRESETS.items())
    rows += [("fair:QxR", resolve("fair:2500x2")),
             ("lhp:QxLxR", resolve("lhp:2500x600x2"))]
    return [(name, sc.summary()) for name, sc in rows]
