"""Lock-performance simulator: coherence machine, topologies, session API.

* ``machine``  — the vectorized MESI-lite machine (§L1 substrate)
* ``topology`` — hierarchical machine models lowering to cost matrices
* ``sched``    — hostile-OS scheduler models lowering to traced scalars
* ``engine``   — ``SimEngine``, the one execution session API
* ``api``      — ``bench_lock`` convenience wrapper + metric aggregation
"""
from repro.core.sim.api import BenchResult, bench_lock    # noqa: F401
from repro.core.sim.engine import (                       # noqa: F401
    GridResult, SimEngine, Workload,
)
from repro.core.sim.machine import CostModel              # noqa: F401
from repro.core.sim.sched import Scheduler                # noqa: F401
from repro.core.sim.topology import (                     # noqa: F401
    PRESETS, Topology, ccx, numa, smp,
)
