"""High-level entry points for the lock-performance simulator.

``bench_lock`` runs the MutexBench workload (paper §7.1) for one algorithm
at a given thread count and returns the paper's metrics:

* throughput (episodes / Mcycle, aggregated over the ensemble)
* misses / episode          (Table 1 "Maximum Remote Misses" family)
* invalidations / episode   (Table 1 "Invalidations per episode")
* remote misses / episode   (NUMA)
* mean contended acquire latency (cycles)
* admission fairness (max/min episodes per thread) and the admission log
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.locks.programs import PROGRAMS
from repro.core.sim.machine import CostModel, run_machine


@dataclass
class BenchResult:
    name: str
    n_threads: int
    throughput: float          # episodes per kilo-cycle (ensemble mean)
    episodes: int
    miss_per_episode: float
    inval_per_episode: float
    remote_per_episode: float
    latency: float             # mean arrive->admit cycles
    unfairness: float          # max/min episodes per thread
    admissions: np.ndarray     # (replicas, ADM_LOG) ring of admitted tids


def summarize_ensemble(name: str, n_threads: int, s) -> BenchResult:
    """Aggregate a replica-stacked ``MachineState`` (leading ensemble axis)
    into the paper's metrics. Shared by ``bench_lock`` and the
    ``repro.bench`` sweep driver."""
    eps = np.asarray(s.episodes).sum(axis=1)           # per replica
    time = np.maximum(np.asarray(s.time), 1)
    thr = float((eps / time).mean() * 1e3)             # per kcycle
    total = max(int(eps.sum()), 1)
    per_thread = np.asarray(s.episodes)
    lo = np.maximum(per_thread.min(axis=1), 1)
    return BenchResult(
        name=name, n_threads=n_threads, throughput=thr,
        episodes=int(eps.sum()),
        miss_per_episode=float(np.asarray(s.misses).sum() / total),
        inval_per_episode=float(np.asarray(s.inval_recv).sum() / total),
        remote_per_episode=float(np.asarray(s.remote).sum() / total),
        latency=float(np.asarray(s.lat_sum).sum() / total),
        unfairness=float((per_thread.max(axis=1) / lo).mean()),
        admissions=np.asarray(s.adm_log),
    )


def bench_lock(name: str, n_threads: int, *, n_steps: int = 20_000,
               ncs_max: int = 0, cs_shared: bool = True,
               cost: CostModel = CostModel(n_nodes=2),
               n_replicas: int = 4, seed0: int = 0) -> BenchResult:
    prog = PROGRAMS[name](n_threads, ncs_max=ncs_max, cs_shared=cs_shared)

    @jax.jit
    def go(seeds):
        return jax.vmap(lambda s: run_machine(prog, n_threads, n_steps,
                                              cost, s))(seeds)

    s = go(jnp.arange(seed0, seed0 + n_replicas))
    return summarize_ensemble(name, n_threads, s)


def sweep_threads(name: str, thread_counts, **kw):
    return [bench_lock(name, t, **kw) for t in thread_counts]
