"""High-level entry points for the lock-performance simulator.

Execution lives in the ``SimEngine`` session API (``core/sim/engine.py``,
DESIGN.md §L1); this module keeps the stable convenience surface —
``bench_lock`` as a thin engine wrapper, plus the metric aggregation
(``BenchResult`` / ``summarize_ensemble`` / ``admission_bypass_bound``)
every caller shares.

``bench_lock`` runs the MutexBench workload (paper §7.1) for one algorithm
at a given thread count and returns the paper's metrics:

* throughput (episodes / Mcycle, aggregated over the ensemble)
* misses / episode          (Table 1 "Maximum Remote Misses" family)
* invalidations / episode   (Table 1 "Invalidations per episode")
* remote misses / episode   (NUMA)
* mean contended acquire latency (cycles)
* admission fairness (max/min episodes per thread) and the admission log
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property

import numpy as np

from repro.core.sim.machine import CostModel


@dataclass
class BenchResult:
    name: str
    n_threads: int
    throughput: float          # episodes per kilo-cycle (ensemble mean)
    episodes: int
    miss_per_episode: float
    inval_per_episode: float
    remote_per_episode: float
    latency: float             # mean arrive->admit cycles
    unfairness: float          # max/min episodes per thread (inf-safe:
                               # the min is clamped to 1, so a starved
                               # thread yields a large finite ratio)
    admissions: np.ndarray     # (replicas, ADM_LOG) ring of admitted tids
    admission_counts: np.ndarray   # (replicas,) total admissions (ring pos)
    aborts: int = 0            # abandoned acquisitions (NCS returns that
                               # completed no episode; timed-wait locks)
    preempts: int = 0          # scheduler preemptions across the ensemble

    @cached_property
    def bypass_bound(self) -> int:
        """Observed single-thread admission-interleave bound, derived
        lazily from the admission log (see ``admission_bypass_bound``) —
        the log decode is host-side Python, so only callers that report
        the bound (locks-ext profile, tests, examples) pay for it."""
        return admission_bypass_bound(self.admissions,
                                      self.admission_counts)


def admission_bypass_bound(adm_log, adm_cnt) -> int:
    """Observed single-thread admission-interleave bound, derived from the
    machine's admission log so callers no longer re-derive it.

    For every pair of *consecutive* admissions of the same thread, count
    how many times each single other thread was admitted in between; the
    bound is the maximum such count over the logged window. On the timed
    machine one interleave per peer is a legitimate re-arrival turn, so
    the paper's thread-specific bounded bypass of <= 1 (§2) shows up as a
    bound of <= 2 for segment-based locks (Table 2's palindrome admits
    the segment interior twice per cycle), exactly 1 for strict-FIFO
    locks, and unbounded growth for barging/LIFO-ish admission.
    """
    worst = 0
    for log, cnt in zip(np.atleast_2d(np.asarray(adm_log)),
                        np.atleast_1d(np.asarray(adm_cnt))):
        K = len(log)
        seq = np.roll(log, -(int(cnt) % K)) if cnt >= K else log[:int(cnt)]
        seq = seq[seq >= 0]
        last: dict = {}
        for i, t in enumerate(seq):
            t = int(t)
            if t in last and i - last[t] > 1:
                _, counts = np.unique(seq[last[t] + 1:i], return_counts=True)
                worst = max(worst, int(counts.max()))
            last[t] = i
    return worst


def summarize_ensemble(name: str, n_threads: int, s) -> BenchResult:
    """Aggregate a replica-stacked ``MachineState`` (leading ensemble axis)
    into the paper's metrics. Shared by ``bench_lock`` and the
    ``repro.bench`` sweep driver.

    ``unfairness`` is inf-safe by construction: the per-thread minimum is
    clamped to one episode, so a starved thread produces a large finite
    ratio rather than ``inf``/``nan``. ``bypass_bound`` is a lazy
    property derived from the admission log by
    :func:`admission_bypass_bound`."""
    eps = np.asarray(s.episodes).sum(axis=1)           # per replica
    time = np.maximum(np.asarray(s.time), 1)
    thr = float((eps / time).mean() * 1e3)             # per kcycle
    total = max(int(eps.sum()), 1)
    per_thread = np.asarray(s.episodes)
    lo = np.maximum(per_thread.min(axis=1), 1)
    return BenchResult(
        name=name, n_threads=n_threads, throughput=thr,
        episodes=int(eps.sum()),
        miss_per_episode=float(np.asarray(s.misses).sum() / total),
        inval_per_episode=float(np.asarray(s.inval_recv).sum() / total),
        remote_per_episode=float(np.asarray(s.remote).sum() / total),
        latency=float(np.asarray(s.lat_sum).sum() / total),
        unfairness=float((per_thread.max(axis=1) / lo).mean()),
        admissions=np.asarray(s.adm_log),
        admission_counts=np.asarray(s.adm_cnt),
        aborts=max(int(np.asarray(s.returns).sum()) - int(eps.sum()), 0),
        preempts=int(np.asarray(s.preempts).sum()),
    )


def bench_lock(name: str, n_threads: int, *, n_steps: int = 20_000,
               ncs_max: int = 0, cs_shared: bool = True,
               cost=CostModel(n_nodes=2),  # noqa: B008
               n_replicas: int = 4, seed0: int = 0,
               builder=None) -> BenchResult:
    """Bench one lock — a thin wrapper over the ``SimEngine`` session API
    (``core/sim/engine.py``). ``cost`` accepts a flat ``CostModel``, a
    ``core.sim.topology.Topology``, or a preset name (``"epyc-2s"``).
    ``builder`` overrides the ``PROGRAMS`` registry lookup — pass
    ``functools.partial(compile_spec, my_spec)`` to bench an unregistered
    ``LockSpec`` (see ``examples/define_a_lock.py``)."""
    from repro.core.sim.engine import SimEngine, Workload
    eng = SimEngine(builder if builder is not None else name, name=name,
                    n_threads=n_threads, topology=cost,
                    workload=Workload(ncs_max=ncs_max, cs=cs_shared,
                                      n_steps=n_steps))
    return eng.ensemble(range(seed0, seed0 + n_replicas))


def sweep_threads(name: str, thread_counts, **kw):
    """Deprecated: use ``SimEngine(...).grid(threads=[...])`` — one
    session, one compile cache, topology/workload axes included."""
    import warnings
    warnings.warn(
        "sweep_threads is deprecated; use repro.core.sim.engine."
        "SimEngine(name, ...).grid(threads=thread_counts)",
        DeprecationWarning, stacklevel=2)
    return [bench_lock(name, t, **kw) for t in thread_counts]
