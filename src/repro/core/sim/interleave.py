"""Interleaving scheduler for the reference lock generators.

Drives T threads through ``loop { NCS; acquire; CS; release }`` interleaved
at atomic-op granularity (sequential consistency). Verifies the mutual-
exclusion invariant on every CS entry and records a timeline of
``arrive`` (doorway completion) / ``admit`` (CS entry) events used by the
fairness, bounded-bypass and palindrome analyses.

Policies:
* ``random``    — uniformly random thread each step (hypothesis drives the
                  seed): the property-test scheduler.
* ``rr``        — deterministic round-robin, one op per thread per round:
                  the sustained-contention regime of the paper (empty NCS,
                  threads re-arrive immediately) — reproduces Table 2.
"""
from __future__ import annotations

import random
from dataclasses import dataclass

from repro.core.locks.reference import LockAlgorithm


class MutualExclusionViolation(AssertionError):
    pass


@dataclass
class RunResult:
    admissions: list                     # thread id per CS entry, in order
    timeline: list                       # ("arrive"|"admit", thread)
    episodes: dict                       # tid -> completed episodes
    ops: int

    # -- analyses ----------------------------------------------------------
    def max_bypass(self) -> int:
        """Thread-specific bounded bypass: over every waiting window
        (arrive -> admit of thread t), the max number of admissions by any
        single OTHER thread that arrived after t. Reciprocating: <= 1."""
        worst = 0
        for i, (kind, t) in enumerate(self.timeline):
            if kind != "arrive":
                continue
            arrived_after: set = set()
            later_adm: dict = {}
            for kind2, t2 in self.timeline[i + 1:]:
                if t2 == t:
                    if kind2 == "admit":
                        break
                    continue
                if kind2 == "arrive":
                    arrived_after.add(t2)
                elif kind2 == "admit" and t2 in arrived_after:
                    later_adm[t2] = later_adm.get(t2, 0) + 1
            if later_adm:
                worst = max(worst, max(later_adm.values()))
        return worst

    def is_fifo(self) -> bool:
        """Admissions in exact doorway (arrival) order?"""
        arr, adm = [], []
        for kind, t in self.timeline:
            (arr if kind == "arrive" else adm).append(t)
        return adm == arr[:len(adm)]

    def unfairness(self) -> float:
        """max/min episodes over threads (paper §9.2: <= 2x for
        reciprocating under sustained contention)."""
        eps = list(self.episodes.values())
        lo = min(eps)
        return float("inf") if lo == 0 else max(eps) / lo

    def cycle(self) -> list | None:
        """Detect a repeating admission cycle in the tail; returns one
        period (e.g. the Table-2 palindrome A B C D E D C B)."""
        s = self.admissions
        n = len(s)
        for period in range(2, n // 3):
            tail = s[n - 3 * period:]
            if tail[:period] == tail[period:2 * period] == tail[2 * period:]:
                return tail[:period]
        return None


def run(alg: LockAlgorithm, n_threads: int, n_ops: int = 4000,
        policy: str = "random", seed: int = 0, ncs_ops: int = 0,
        max_episodes: int | None = None) -> RunResult:
    rng = random.Random(seed)
    in_cs: list = []
    episodes = {t: 0 for t in range(n_threads)}
    admissions: list = []
    timeline: list = []

    def thread_body(t: int):
        while True:
            for _ in range(ncs_ops):
                yield ("delay",)
            ctx = yield from alg.acquire(t)
            if in_cs:
                raise MutualExclusionViolation(
                    f"{alg.name}: thread {t} entered CS while "
                    f"{in_cs} inside")
            in_cs.append(t)
            timeline.append(("admit", t))
            admissions.append(t)
            yield ("cs",)
            in_cs.remove(t)
            episodes[t] += 1
            yield from alg.release(t, ctx)

    gens = {t: thread_body(t) for t in range(n_threads)}
    pending: dict = {t: None for t in range(n_threads)}
    started: set = set()

    def step_thread(t):
        g = gens[t]
        op = g.send(pending[t]) if t in started else next(g)
        started.add(t)
        kind = op[0]
        res = None
        if kind == "load":
            res = op[1].v
        elif kind == "store":
            op[1].v = op[2]
        elif kind == "xchg":
            res, op[1].v = op[1].v, op[2]
            if op[-1] == "arrive":
                timeline.append(("arrive", t))
        elif kind == "faa":
            res, op[1].v = op[1].v, op[1].v + op[2]
            if op[-1] == "arrive":
                timeline.append(("arrive", t))
        elif kind == "cas":
            old = op[1].v
            ok = old == op[2]
            if ok:
                op[1].v = op[3]
            res = (old, ok)
        elif kind == "arrive":
            timeline.append(("arrive", t))
        pending[t] = res

    steps = 0
    while steps < n_ops:
        if max_episodes is not None and len(admissions) >= max_episodes:
            break
        if policy == "rr":
            for t in range(n_threads):
                step_thread(t)
                steps += 1
        else:
            step_thread(rng.randrange(n_threads))
            steps += 1

    return RunResult(admissions=admissions, timeline=timeline,
                     episodes=episodes, ops=steps)
