"""Vectorized cache-coherent shared-memory machine in JAX.

This is the performance substrate on which the lock algorithms are
evaluated (paper Figures 1-3, Table 1): a sequentially-consistent machine
with a MESI-lite per-word coherence model and a serialized coherence bus.

Model (DESIGN.md §L1):
* ``mem[W]``      — one word per cache line (the paper sequesters every
                    field at 128B, so word == line is faithful).
* ``owner[W]``    — thread holding the line Modified (-1: none).
* ``sharers[T,W]``— Shared copies.
* Loads:  hit (owner==t or sharer) costs 1 cycle and no bus traffic;
          a miss pays the distance-in-hierarchy transfer cost between
          the requester and the line's *home* thread — a traced
          ``LoweredCost.miss[t, home]`` lookup, lowered from either the
          flat ``CostModel`` or a ``topology.Topology`` tree — and
          downgrades a remote Modified copy to Shared.
* Stores/atomics: hit-in-M costs 1; otherwise a miss that *invalidates*
  all other copies (counted per victim — the paper's l2d_cache_inval).
* The bus serializes misses (global_time advances only on line transfers);
  cache hits and local DELAYs only advance the thread's own clock. This is
  what makes global spinning (Ticket) collapse at high T while local
  spinning (MCS/CLH/Reciprocating) hands off in O(1) bus transactions.
* SPIN ops block the thread (zero cost) until the watched word is written
  — a woken waiter then pays the coherence miss for its re-read, exactly
  the "local spinning" accounting of the paper.

Op/result-encoding contract (the single source of truth — the lock DSL
(``core/locks/dsl.py``) and the lock specs reference this table instead of
restating it):

  kind     operands              result ``res`` fed to the next handler
  -------  --------------------  ---------------------------------------
  NOP      (addr ignored, use 0) mem[addr]
  LOAD     addr                  mem[addr]
  STORE    addr, a=value         old mem[addr] (by convention ignored)
  XCHG     addr, a=value         old mem[addr]
  CAS      addr, a=expect,       ``old * 2 + ok`` — the old value and the
           b=new                 success bit packed in one word (all lock
                                 words are small and non-negative)
  FAA      addr, a=delta         old mem[addr]
  SPIN_EQ  addr, a               block (zero cost) until mem[addr] == a;
                                 res = the watched value once satisfied
  SPIN_NE  addr, a               block until mem[addr] != a; res likewise
  PARK_EQ  addr, a               SPIN_EQ semantics, plus the park cost
                                 model: ``CostModel.park_cost`` is charged
                                 when the thread blocks (the kernel-entry
                                 syscall) and ``CostModel.unpark_cost``
                                 when a writer wakes it — the unpark is a
                                 syscall *the waker executes*, so its
                                 cycles accrue to the waker's own
                                 timeline; the sleeper becomes runnable
                                 at the waking store's finish time
  PARK_EQ_TIMEOUT / PARK_NE_TIMEOUT
           addr, a, b=timeout    abortable waiting (the lock DSL's
                                 ``abort`` phase): PARK_EQ / PARK-NE
                                 blocking, but the wait gives up after
                                 ``b`` private cycles. Result packs like
                                 CAS: ``watched * 2 + ok`` — ok == 1 when
                                 the condition was met, 0 when the wait
                                 timed out (the abort path runs next)
  DELAY    a=cycles              advance only the issuing thread's clock;
                                 res = mem[addr] (use addr 0)

Scheduler (hostile OS) model — ``machine_step`` also consumes a
:class:`LoweredSched` (from ``core/sim/sched.py``), pure traced data like
``LoweredCost``: the earliest-ready selection key *is* the runnable mask.
A thread whose on-core slice exceeds the (seeded-jittered) quantum is
descheduled after its current op: its ``ready_at`` jumps by the
oversubscription gap plus ``CostModel.resched_cost`` (the re-dispatch
charge), which freezes its PC and stops its coherence traffic until
re-dispatch; a woken parker additionally pays one re-dispatch when cores
are oversubscribed. The degenerate scheduler (infinite quantum,
cores >= threads) makes every term collapse to the schedulerless
arithmetic — bit-identical states, pinned by tests/test_hostile.py.

Value/address conventions shared by every program: LOCKEDEMPTY == 1 marks
a detached-but-empty arrival word (so real element addresses must be > 1);
word 4 is the shared CS word, word 5 the second (read-only-profile) CS
word, words 0..3 are lock words, and per-thread wait elements live at
addresses >= 8.

Lock algorithms are table-driven state machines (``jax.lax.switch`` over a
per-algorithm handler list) authored as declarative ``LockSpec`` phase
specs (``core/locks/dsl.py``) and lowered by ``core/locks/compile.py`` to
the ``Program`` handler-table form below; the engine is a single
``jax.lax.scan`` over micro-steps, ``jax.vmap``-able over replica
ensembles and jit-compiled end to end.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

I32 = jnp.int32
F32 = jnp.float32
INF = jnp.array(2**31 - 1, jnp.int32)

# op kinds (semantics: the contract table in the module docstring)
NOP, LOAD, STORE, XCHG, CAS, FAA, SPIN_EQ, SPIN_NE, DELAY, PARK_EQ = \
    range(10)
PARK_EQ_TIMEOUT, PARK_NE_TIMEOUT = 10, 11


class Op(NamedTuple):
    kind: jnp.ndarray
    addr: jnp.ndarray
    a: jnp.ndarray
    b: jnp.ndarray


def op(kind, addr=0, a=0, b=0):
    return (jnp.asarray(kind, I32), jnp.asarray(addr, I32),
            jnp.asarray(a, I32), jnp.asarray(b, I32))


@dataclass(frozen=True)
class CostModel:
    """Flat two-tier cost model (one local/remote pair, contiguous
    thread->node split). Still accepted everywhere; richer machines are
    described by ``core.sim.topology.Topology``. Both lower to the same
    :class:`LoweredCost` thread x thread matrix the engine consumes."""
    hit: int = 1
    local_miss: int = 40
    remote_miss: int = 100
    n_nodes: int = 1          # NUMA nodes (threads split contiguously)
    # PARK_EQ hooks (spin-then-park locks): cycles charged on the blocking
    # park itself (kernel entry) and on the wake handoff (context switch).
    # Neither advances the coherence bus — parking is private time. The
    # park is paid by the sleeper; the unpark syscall by the *waker*.
    park_cost: int = 25
    unpark_cost: int = 75
    # Re-dispatch charge after a scheduler deschedule (or an oversubscribed
    # wake): the context-switch-in cost. Private time, like parking.
    resched_cost: int = 150


class LoweredCost(NamedTuple):
    """The one cost interface ``machine_step`` consumes: a *traced*
    thread x thread transfer-cost lookup. ``miss[t, h]`` is the cycles a
    coherence miss pays when thread ``t`` pulls a line homed with thread
    ``h`` (the distance-in-hierarchy lookup); ``remote[t, h]`` marks
    NUMA-remote transfers (the ``remote_per_episode`` metric). Every
    field is data, not shape — a grid of machines is a stacked batch of
    these, vmapped through one XLA program (``core.sim.engine``)."""
    hit: jnp.ndarray          # () i32
    miss: jnp.ndarray         # (T, T) i32  requester x home-thread
    remote: jnp.ndarray       # (T, T) bool
    park: jnp.ndarray         # () i32
    unpark: jnp.ndarray       # () i32
    resched: jnp.ndarray      # () i32


class LoweredSched(NamedTuple):
    """The lowered hostile-OS scheduler ``machine_step`` consumes —
    scalar traced data (like ``LoweredCost``, a grid of schedulers is a
    stacked batch sharing one jit; ``core/sim/sched.py`` builds these).
    ``quantum`` is the on-core timeslice in cycles (INF: never preempt),
    ``lhp_quantum`` the tighter slice applied while the thread holds the
    lock (lock-holder-preemption bias; INF: same as ``quantum``),
    ``cores`` the physical core count (cores < T: oversubscribed — a
    preempted thread waits out the other threads' quanta on its core),
    and ``jitter`` the seeded per-slice budget jitter span in cycles
    (deterministic preemption points from the per-thread xorshift)."""
    quantum: jnp.ndarray      # () i32
    lhp_quantum: jnp.ndarray  # () i32
    cores: jnp.ndarray        # () i32
    jitter: jnp.ndarray      # () i32


def lower_sched(sched, n_threads: int) -> LoweredSched:
    """Lower any scheduler description — ``None`` (the degenerate
    always-running OS), a ``sched.Scheduler`` (via its ``.lower``), or an
    already-lowered :class:`LoweredSched` — to the scalar form."""
    if sched is None:
        return LoweredSched(quantum=INF, lhp_quantum=INF,
                            cores=jnp.asarray(n_threads, I32),
                            jitter=jnp.zeros((), I32))
    if isinstance(sched, LoweredSched):
        return sched
    return sched.lower(n_threads)


def lower_cost(cm, n_threads: int) -> LoweredCost:
    """Lower any cost description — a flat :class:`CostModel`, a
    ``topology.Topology`` (via its ``.lower``), or an already-lowered
    :class:`LoweredCost` — to the matrix form. The flat lowering uses the
    historical contiguous-split node arithmetic, so it is bit-identical
    to the pre-topology branch; it stays pure data-flow, so a traced
    ``n_nodes`` still shares one compile across NUMA variants."""
    if isinstance(cm, LoweredCost):
        return cm
    lower = getattr(cm, "lower", None)
    if lower is not None:                 # Topology (duck-typed: no import
        return lower(n_threads)           # cycle with core.sim.topology)
    t = jnp.arange(n_threads)
    node = _node(t, n_threads, cm.n_nodes)
    remote = (node[:, None] != node[None, :]) & (cm.n_nodes > 1)
    return LoweredCost(
        hit=jnp.asarray(cm.hit, I32),
        miss=jnp.where(remote, cm.remote_miss, cm.local_miss).astype(I32),
        remote=remote,
        park=jnp.asarray(cm.park_cost, I32),
        unpark=jnp.asarray(cm.unpark_cost, I32),
        resched=jnp.asarray(cm.resched_cost, I32))


@dataclass(frozen=True)
class Program:
    """A lock+workload program: handlers[pc](t, regs, res, rng) ->
    (regs, next_pc, op4, arrive, admit, rng).

    ``home`` maps each word to the thread on whose NUMA node the line is
    homed (-1: lock/global words, homed on node 0). The paper's "Maximum
    Remote Misses" analysis assumes home-based snooping (UPI), so remote-ness
    is decided by the line's home, not its last writer."""
    handlers: tuple
    n_mem: int
    home: tuple = ()          # per-word home thread (-1 => node 0)
    name: str = "prog"
    n_regs: int = 8
    init_mem: tuple = ()      # ((addr, value), ...) initial memory words


class MachineState(NamedTuple):
    mem: jnp.ndarray          # (W,) i32
    owner: jnp.ndarray        # (W,) i32
    sharers: jnp.ndarray      # (T, W) bool
    last_writer: jnp.ndarray  # (W,) i32
    pc: jnp.ndarray           # (T,) i32
    regs: jnp.ndarray         # (T, R) i32
    cur_op: jnp.ndarray       # (T, 4) i32
    blocked: jnp.ndarray      # (T,) bool
    ready_at: jnp.ndarray     # (T,) i32
    time: jnp.ndarray         # () i32 bus clock
    rng: jnp.ndarray          # (T,) u32 xorshift state
    # metrics
    episodes: jnp.ndarray     # (T,) i32
    misses: jnp.ndarray       # (T,) i32
    remote: jnp.ndarray       # (T,) i32
    inval_recv: jnp.ndarray   # (T,) i32
    arrive_time: jnp.ndarray  # (T,) i32
    lat_sum: jnp.ndarray      # (T,) i32
    adm_log: jnp.ndarray      # (K,) i32
    adm_cnt: jnp.ndarray      # () i32
    # abortable waiting (PARK_*_TIMEOUT): per-thread wake deadline
    # (INF: no timed wait pending)
    timeout_at: jnp.ndarray   # (T,) i32
    # hostile-OS scheduler state/metrics (degenerate scheduler: inert)
    in_cs: jnp.ndarray        # (T,) bool  lock held (admit .. NCS return)
    slice_used: jnp.ndarray   # (T,) i32   on-core cycles this timeslice
    sched_rng: jnp.ndarray    # (T,) u32   preemption-point xorshift
    preempts: jnp.ndarray     # (T,) i32   involuntary deschedules
    returns: jnp.ndarray      # (T,) i32   episodes ended (NCS returns);
                              #            returns - episodes = aborts


ADM_LOG = 512


def init_state(prog: Program, n_threads: int, seed: int = 0) -> MachineState:
    T, W, R = n_threads, prog.n_mem, prog.n_regs
    mem0 = jnp.zeros(W, I32)
    for a, v in prog.init_mem:
        mem0 = mem0.at[a].set(v)
    return MachineState(
        mem=mem0,
        owner=jnp.full(W, -1, I32),
        sharers=jnp.zeros((T, W), bool),
        last_writer=jnp.full(W, -1, I32),
        pc=jnp.zeros(T, I32),
        regs=jnp.zeros((T, R), I32),
        cur_op=jnp.broadcast_to(jnp.array([NOP, 0, 0, 0], I32), (T, 4)),
        blocked=jnp.zeros(T, bool),
        ready_at=jnp.zeros(T, jnp.int32),
        time=jnp.zeros((), jnp.int32),
        rng=(jnp.arange(T, dtype=jnp.uint32) * jnp.uint32(2654435761)
             + jnp.uint32(seed) * jnp.uint32(97) + jnp.uint32(1)),
        episodes=jnp.zeros(T, I32),
        misses=jnp.zeros(T, I32),
        remote=jnp.zeros(T, I32),
        inval_recv=jnp.zeros(T, I32),
        arrive_time=jnp.zeros(T, jnp.int32),
        lat_sum=jnp.zeros(T, jnp.int32),
        adm_log=jnp.full(ADM_LOG, -1, I32),
        adm_cnt=jnp.zeros((), I32),
        timeout_at=jnp.full(T, INF, I32),
        in_cs=jnp.zeros(T, bool),
        slice_used=jnp.zeros(T, I32),
        # scheduler stream: distinct from the NCS rng so the hostile layer
        # never perturbs the workload's random delays
        sched_rng=((jnp.arange(T, dtype=jnp.uint32) + jnp.uint32(7))
                   * jnp.uint32(2246822519)
                   ^ (jnp.uint32(seed) * jnp.uint32(40503)
                      + jnp.uint32(11))),
        preempts=jnp.zeros(T, I32),
        returns=jnp.zeros(T, I32),
    )


def _node(t, T, n_nodes):
    return jnp.where(n_nodes <= 1, 0, t // jnp.maximum(T // n_nodes, 1))


def machine_step(s: MachineState, prog: Program, cm, n_threads: int,
                 sched=None):
    """Execute one micro-op for the earliest-ready unblocked thread.
    ``cm`` is any cost description ``lower_cost`` accepts (flat
    ``CostModel``, ``topology.Topology``, or a ``LoweredCost``);
    ``sched`` any scheduler description ``lower_sched`` accepts (``None``
    — the degenerate always-running OS — a ``sched.Scheduler``, or a
    ``LoweredSched``)."""
    T = n_threads
    lc = lower_cost(cm, T)
    ls = lower_sched(sched, T)

    # Runnable mask / dispatch key: a blocked thread is dispatchable only
    # at its abort deadline (INF for plain SPIN/PARK waits); a descheduled
    # thread's preemption gap is folded into ready_at, so "not runnable"
    # is simply "keyed in the future" — PC frozen, no coherence traffic.
    keyed = jnp.where(s.blocked, s.timeout_at, s.ready_at)
    t = jnp.argmin(keyed).astype(I32)
    kind, addr, a, b = (s.cur_op[t, 0], s.cur_op[t, 1], s.cur_op[t, 2],
                        s.cur_op[t, 3])
    mval = s.mem[addr]
    start = jnp.maximum(s.time, keyed[t])

    is_park_to = (kind == PARK_EQ_TIMEOUT) | (kind == PARK_NE_TIMEOUT)
    is_park = (kind == PARK_EQ) | is_park_to
    is_load = (kind == LOAD) | (kind == SPIN_EQ) | (kind == SPIN_NE) | is_park
    is_store = (kind == STORE) | (kind == XCHG) | (kind == CAS) | (kind == FAA)
    is_mem = is_load | is_store

    # --- spin semantics: unsatisfied -> block (woken by a write); a timed
    # wait whose deadline has passed completes instead, with ok == 0 ------
    eq_wait = (kind == SPIN_EQ) | (kind == PARK_EQ) | \
              (kind == PARK_EQ_TIMEOUT)
    ne_wait = (kind == SPIN_NE) | (kind == PARK_NE_TIMEOUT)
    unsat = (eq_wait & (mval != a)) | (ne_wait & (mval == a))
    timed_out = is_park_to & unsat & (s.timeout_at[t] <= start)
    spin_unsat = unsat & ~timed_out

    # --- cache/cost: distance-in-hierarchy lookup ---------------------------
    hit = (s.owner[addr] == t) | s.sharers[t, addr]
    home_arr = jnp.asarray(prog.home if prog.home else (-1,) * prog.n_mem,
                           I32)
    # home == -1 homes the word with thread 0 (lock/global words, node 0)
    eff_home = jnp.maximum(home_arr[addr], 0)
    remote = lc.remote[t, eff_home]
    miss = is_mem & ~hit
    cost = jnp.where(~is_mem, 0,
                     jnp.where(hit & ~is_store, lc.hit,
                               jnp.where(hit & is_store & (s.owner[addr] == t),
                                         lc.hit,
                                         lc.miss[t, eff_home])))
    # a store to a merely-Shared line is an upgrade: count as miss-ish
    upgrade = is_store & s.sharers[t, addr] & (s.owner[addr] != t)
    miss = miss | upgrade

    # --- memory effect ------------------------------------------------------
    cas_ok = (kind == CAS) & (mval == a)
    newval = jnp.where(kind == STORE, a,
             jnp.where(kind == XCHG, a,
             jnp.where(kind == FAA, mval + a,
             jnp.where(cas_ok, b, mval))))
    writes = is_store & ((kind != CAS) | cas_ok)
    # failed CAS still takes the line exclusive (x86 semantics)
    takes_line = is_store
    res = jnp.where(kind == CAS, mval, jnp.where(is_load, mval, mval))
    res = jnp.where(kind == XCHG, mval, res)
    res = jnp.where(kind == FAA, mval, res)
    cas_flag = jnp.where(cas_ok, 1, 0)

    do_exec = ~spin_unsat
    eff = do_exec & is_mem

    mem = s.mem.at[addr].set(jnp.where(do_exec & writes, newval, s.mem[addr]))

    # coherence updates
    sh_col = s.sharers[:, addr]
    others_sharing = sh_col & (jnp.arange(T) != t)
    n_inval = jnp.where(do_exec & takes_line,
                        others_sharing.sum() +
                        ((s.owner[addr] >= 0) & (s.owner[addr] != t)),
                        0)
    inval_recv = s.inval_recv + jnp.where(
        (do_exec & takes_line),
        others_sharing.astype(I32) +
        (jnp.arange(T) == s.owner[addr]) * (s.owner[addr] != t), 0)

    # store: invalidate everyone else, become owner
    # load miss: downgrade owner to shared, join sharers
    new_sh_col = jnp.where(do_exec & takes_line,
                           jnp.arange(T) == t,
                           jnp.where(eff & is_load,
                                     sh_col | (jnp.arange(T) == t) |
                                     (jnp.arange(T) == s.owner[addr]),
                                     sh_col))
    sharers = s.sharers.at[:, addr].set(new_sh_col)
    owner = s.owner.at[addr].set(
        jnp.where(do_exec & takes_line, t,
                  jnp.where(eff & is_load & ~hit, -1, s.owner[addr])))
    last_writer = s.last_writer.at[addr].set(
        jnp.where(do_exec & writes, t, s.last_writer[addr]))

    # --- timing -------------------------------------------------------------
    # spin first-check also pays its read cost before blocking
    op_cost = jnp.where(kind == DELAY, a.astype(jnp.int32),
                        cost.astype(jnp.int32))
    # a blocking PARK additionally pays the kernel-entry park cost;
    # it is private time, so only the probe's line transfer hits the bus
    bus_finish = start + op_cost
    finish = bus_finish + jnp.where(is_park & spin_unsat, lc.park, 0)
    # bus serializes only on misses (line transfers)
    time = jnp.where(eff & miss | (spin_unsat & ~hit), bus_finish, s.time)
    ready_at = s.ready_at.at[t].set(finish)
    misses_ct = s.misses.at[t].add(
        jnp.where((eff | spin_unsat) & miss, 1, 0))
    remote_ct = s.remote.at[t].add(
        jnp.where((eff | spin_unsat) & miss & remote, 1, 0))
    # spin's failed probe still cached the line Shared
    sharers = sharers.at[t, addr].set(
        jnp.where(spin_unsat, True, sharers[t, addr]))

    # abortable waiting: arm the deadline on the *first* block of a timed
    # park (spurious wakes keep the original deadline); any completion —
    # satisfied or timed out — disarms it
    timeout_at = s.timeout_at.at[t].set(
        jnp.where(do_exec, INF,
                  jnp.where(spin_unsat & is_park_to
                            & (s.timeout_at[t] == INF),
                            finish + b, s.timeout_at[t])))

    # --- wake threads blocked on this word ----------------------------------
    woke = (do_exec & writes) & s.blocked & (s.cur_op[:, 1] == addr)
    parked = ((s.cur_op[:, 0] == PARK_EQ)
              | (s.cur_op[:, 0] == PARK_EQ_TIMEOUT)
              | (s.cur_op[:, 0] == PARK_NE_TIMEOUT))
    blocked = jnp.where(woke, False, s.blocked)
    # the unpark is a syscall the *waker* executes: its cycles accrue to
    # t's own timeline (one fee per parked sleeper this store wakes)
    ready_at = ready_at.at[t].add(lc.unpark * (woke & parked).sum())
    # the sleeper becomes runnable at the waking store's finish; on an
    # oversubscribed machine a woken parker also waits out one re-dispatch
    redisp = jnp.where((ls.cores < T) & parked, lc.resched, 0)
    # a spin-waiter busy-waits *on-core*: its blocked wall-time counts
    # against its slice budget (a parked waiter sleeps off-core), so the
    # scheduler eventually deschedules long spinners — charged as a
    # deferred gap at the spinner's next dispatch
    spin_span = jnp.maximum(finish - s.ready_at, 0)
    slice_used = jnp.where(woke & ~parked, s.slice_used + spin_span,
                           s.slice_used)
    ready_at = jnp.where(woke, jnp.maximum(ready_at, finish) + redisp,
                         ready_at)
    blocked = blocked.at[t].set(spin_unsat)

    # --- transition (only when the op completed) -----------------------------
    def run_handler(pc_regs_res):
        pc_v, regs_v, res_v, rng_v = pc_regs_res
        outs = jax.lax.switch(
            pc_v, [partial(h, t) for h in prog.handlers], regs_v, res_v,
            rng_v)
        return outs   # (regs, next_pc, op4, arrive, admit, rng)

    # timed parks pack like CAS: watched * 2 + ok (ok == 0: wait aborted)
    res_in = jnp.where(kind == CAS, mval * 2 + cas_flag,
                       jnp.where(is_park_to,
                                 mval * 2 + jnp.where(timed_out, 0, 1), res))
    regs_t, next_pc, next_op, arrive, admit, rng_t = run_handler(
        (s.pc[t], s.regs[t], res_in, s.rng[t]))

    adv = do_exec
    pc = s.pc.at[t].set(jnp.where(adv, next_pc, s.pc[t]))
    regs = s.regs.at[t].set(jnp.where(adv, regs_t, s.regs[t]))
    cur_op = s.cur_op.at[t].set(
        jnp.where(adv, jnp.stack(next_op), s.cur_op[t]))
    rng = s.rng.at[t].set(jnp.where(adv, rng_t, s.rng[t]))

    arrive = adv & arrive
    admit = adv & admit
    arrive_time = s.arrive_time.at[t].set(
        jnp.where(arrive, finish, s.arrive_time[t]))
    lat_sum = s.lat_sum.at[t].add(
        jnp.where(admit, finish - s.arrive_time[t], 0))
    episodes = s.episodes.at[t].add(jnp.where(admit, 1, 0))
    adm_log = s.adm_log.at[s.adm_cnt % ADM_LOG].set(
        jnp.where(admit, t, s.adm_log[s.adm_cnt % ADM_LOG]))
    adm_cnt = s.adm_cnt + jnp.where(admit, 1, 0)

    # every return to the NCS top (admitted or abort path) — so
    # returns - episodes counts aborted acquisitions
    ret = adv & (next_pc == 0) & (s.pc[t] != 0)
    returns = s.returns.at[t].add(jnp.where(ret, 1, 0))
    # lock-held window: admission .. NCS return (CS plus release path),
    # the span the lhp_quantum bias tightens
    holding = jnp.where(admit, True, jnp.where(ret, False, s.in_cs[t]))
    in_cs = s.in_cs.at[t].set(holding)

    # --- hostile-OS scheduler: deschedule after the op if over budget -------
    # on-core cycles this dispatch (incl. private park/delay time)
    burn = finish - start
    slice_new = slice_used[t] + burn
    q_eff = jnp.minimum(jnp.where(holding, ls.lhp_quantum, ls.quantum),
                        ls.quantum)
    jit_off = jnp.where(
        ls.jitter > 0,
        (s.sched_rng[t] % (jnp.maximum(ls.jitter, 1).astype(jnp.uint32)
                           + jnp.uint32(1))).astype(I32), 0)
    budget = q_eff - jit_off
    preempt = adv & (slice_new >= budget)
    # a preempted thread waits out the other runnables' *base* quanta on
    # its core (their slices are not lhp-tightened), then pays the
    # re-dispatch; the gap collapses to 0 on a dedicated machine
    # (cores == T), and preempt never fires there (budget == INF)
    gap = ((ls.quantum - jit_off) * (jnp.asarray(T, I32) - ls.cores)
           // jnp.maximum(ls.cores, 1))
    ready_at = ready_at.at[t].add(
        jnp.where(preempt, gap + lc.resched, 0))
    # the slice empties on a deschedule or an off-core park; a spin-block
    # keeps accruing (the busy-wait never yields the core voluntarily)
    slice_used = slice_used.at[t].set(
        jnp.where(preempt | (spin_unsat & is_park), 0, slice_new))
    sr = s.sched_rng[t]
    sr = sr ^ (sr << jnp.uint32(13))
    sr = sr ^ (sr >> jnp.uint32(17))
    sr = sr ^ (sr << jnp.uint32(5))
    sched_rng = s.sched_rng.at[t].set(
        jnp.where(preempt, sr, s.sched_rng[t]))
    preempts = s.preempts.at[t].add(jnp.where(preempt, 1, 0))

    return MachineState(mem, owner, sharers, last_writer, pc, regs, cur_op,
                        blocked, ready_at, time, rng, episodes, misses_ct,
                        remote_ct, inval_recv, arrive_time, lat_sum,
                        adm_log, adm_cnt, timeout_at, in_cs, slice_used,
                        sched_rng, preempts, returns)


def run_machine(prog: Program, n_threads: int, n_steps: int,
                cm=CostModel(), seed: int = 0,  # noqa: B008
                sched=None) -> MachineState:
    """One replica. ``cm``: flat ``CostModel``, ``topology.Topology``, or
    ``LoweredCost``; ``sched``: ``None``, ``sched.Scheduler``, or
    ``LoweredSched`` — both lowered once, outside the scan."""
    s0 = init_state(prog, n_threads, seed)
    lc = lower_cost(cm, n_threads)
    ls = lower_sched(sched, n_threads)

    def body(s, _):
        return machine_step(s, prog, lc, n_threads, ls), None

    s, _ = jax.lax.scan(body, s0, None, length=n_steps)
    return s


def run_ensemble(prog: Program, n_threads: int, n_steps: int,
                 cm=CostModel(), n_replicas: int = 8,  # noqa: B008
                 seed0: int = 0):
    """Deprecated: forward to ``core.sim.engine.SimEngine(...).states``,
    the one session API (same stacked-``MachineState`` return)."""
    import warnings

    from repro.core.sim.engine import SimEngine, Workload
    warnings.warn(
        "run_ensemble is deprecated; use repro.core.sim.engine."
        "SimEngine(prog, topology=..., workload=...).states(seeds)",
        DeprecationWarning, stacklevel=2)
    eng = SimEngine(prog, topology=cm, n_threads=n_threads,
                    workload=Workload(n_steps=n_steps))
    return eng.states(range(seed0, seed0 + n_replicas))
