"""``SimEngine``: the one execution session API over the lock simulator.

Historically the simulator grew five overlapping free-function entry
points (``run_machine``, ``run_ensemble``, ``bench_lock``, ``run_grid``,
``bench_cell``) that each re-plumbed a flat ``CostModel`` by hand. The
engine replaces them with a single composable session:

    eng = SimEngine("reciprocating", topology=numa(2, 8),
                    workload=Workload(ncs_max=250))
    r   = eng.run(seed=0)                     # one BenchResult
    r   = eng.ensemble(range(4))              # seed ensemble, one jit
    g   = eng.grid(seeds=range(4),            # seed x topology batched
                   topologies=[smp(16), numa(2, 8), "epyc-2s"],
                   workloads=["max_contention", "readonly"],
                   schedulers=["dedicated", "fair-4x"],
                   threads=[8, 16])
    g.cell(topology="numa2x8", workload="readonly").result.throughput

Batching contract (what the compile-count CI assertion pins): the seed,
topology and *scheduler* axes are *data* — every topology lowers to a
stacked ``LoweredCost`` thread x thread matrix batch, every scheduler to
a stacked ``LoweredSched`` scalar batch (``core/sim/sched.py``), and the
whole batch runs through **one jit per (threads, workload) shape**.
Thread counts change array shapes and workloads change the compiled
program, so each pair gets exactly one entry in the session's explicit
compile cache; re-running the same shape costs zero new XLA traces.
``self.compiles`` counts real traces (incremented from inside the traced
function), and ``GridResult.compiles`` reports how many a given grid
call paid. Per-session counters under-count the *process*: code that
builds a fresh engine per call (``api.bench_lock``, ad-hoc scripts)
pays traces no session sees, so suite-level accounting (BENCH_trend)
reads the module-wide ``trace_count()`` instead — it is bumped from the
same trace-time site as ``self.compiles`` for every engine in the
process.

Sharded execution: ``SimEngine(shard=...)`` (or the per-call ``shard=``
override on ``grid``) routes the vmapped point batch through
``shard_map`` over a 1-D device mesh, splitting the stacked
seed x topology x scheduler axis across devices. ``"auto"`` (the
default) shards only when >1 device is visible, so single-device hosts
fall back transparently to the plain vmap path; ``True`` forces the
shard_map path even on one device (a mesh of 1 — what the differential
equality tests exercise in-process). Batches are padded to a multiple
of the shard count by replicating the last point and trimmed after the
run; every point is an independent element-wise simulation, so sharded
and unsharded grids are bit-identical (pinned by
``tests/test_sweep_cache.py``).

``bench_lock`` / ``sweep_threads`` (core.sim.api), ``run_ensemble``
(core.sim.machine) and the ``repro.bench`` sweep driver are now thin
wrappers or deprecation shims over this class. See DESIGN.md §L1 for
the topology model and docs/RESULTS.md's topology section for what the
grid axes buy.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sim import sched as schedmod
from repro.core.sim import topology as topo
from repro.core.sim.api import BenchResult, summarize_ensemble
from repro.core.sim.machine import (
    CostModel, LoweredCost, LoweredSched, Program, lower_cost, run_machine,
)

__all__ = ["Workload", "WORKLOADS", "SimEngine", "GridCell", "GridResult",
           "cost_label", "sched_label", "session", "trace_count"]


# --- process-wide trace accounting -------------------------------------------

_TRACES = 0


def _bump_traces() -> None:
    global _TRACES
    _TRACES += 1


def trace_count() -> int:
    """Process-wide count of fresh simulator XLA traces, across *every*
    engine — including throwaway ones no session counter sees. Deltas of
    this are what ``BENCH_trend.json`` reports per suite run."""
    return _TRACES


# --- sharded execution -------------------------------------------------------

_SHARD_BROKEN = False     # sticky: mesh construction failed once, stay off


@functools.lru_cache(maxsize=None)
def _mesh(n_shards: int):
    from repro.sharding.compat import make_mesh
    return make_mesh((n_shards,), ("cells",))


def _resolve_shards(mode, n_points: int) -> int:
    """Shard count for a batch of ``n_points``: 0 means the plain vmap
    path; k >= 1 wraps the vmap in ``shard_map`` over a k-device mesh.
    ``"auto"`` shards only when >1 device is visible; ``True`` forces
    the shard_map path even on one device; an int asks for that many
    shards (clamped to the device count)."""
    global _SHARD_BROKEN
    if mode in (None, False, 0):
        return 0
    try:
        n_dev = jax.device_count()
    except Exception:          # pragma: no cover - jax always has devices
        return 0
    if mode == "auto":
        k = n_dev if n_dev > 1 else 0
    elif mode is True:
        k = max(n_dev, 1)
    else:
        k = max(min(int(mode), n_dev), 1)
    if k and not _SHARD_BROKEN:
        try:
            _mesh(k)
        except Exception:      # no usable mesh: fall back transparently
            _SHARD_BROKEN = True
            k = 0
    return 0 if _SHARD_BROKEN else k


# --- workloads ---------------------------------------------------------------

@dataclass(frozen=True)
class Workload:
    """MutexBench workload knobs (paper §7.1) as one value: the random
    NCS delay bound, the CS profile (``"rw"``/``"ro"``/``"local"`` or the
    historical bool), and the horizon in machine micro-steps."""
    ncs_max: int = 0
    cs: object = True
    n_steps: int = 20_000
    label: str = ""

    @property
    def cs_mode(self) -> str:
        return self.cs if isinstance(self.cs, str) else (
            "rw" if self.cs else "local")

    @property
    def name(self) -> str:
        return self.label or f"{self.cs_mode}/ncs{self.ncs_max}"


#: Named workloads mirroring the paper's evaluation regimes.
WORKLOADS: dict = {
    "max_contention": Workload(0, "rw", label="max_contention"),
    "random_ncs": Workload(250, "rw", label="random_ncs"),
    "readonly": Workload(60, "ro", label="readonly"),
    "local_cs": Workload(0, "local", label="local_cs"),
}


def resolve_workload(w) -> Workload:
    if isinstance(w, Workload):
        return w
    try:
        return WORKLOADS[w]
    except (KeyError, TypeError):
        raise KeyError(f"unknown workload {w!r}; named workloads: "
                       f"{sorted(WORKLOADS)}") from None


# --- cost descriptions -------------------------------------------------------

def _resolve_cost(t):
    """Topology | CostModel | LoweredCost | preset-name string."""
    if isinstance(t, str):
        return topo.resolve(t)
    return t


def cost_label(t) -> str:
    """Stable display label for a grid's topology axis."""
    t = _resolve_cost(t)
    if isinstance(t, topo.Topology):
        return t.name
    if isinstance(t, CostModel):
        lab = f"flat:{t.n_nodes}"
        if (t.park_cost, t.unpark_cost) != (CostModel.park_cost,
                                            CostModel.unpark_cost):
            lab += f"/park{t.park_cost}+{t.unpark_cost}"
        return lab
    return "lowered"


def _lower_host(t, n_threads: int) -> tuple:
    """Lower to host ``(hit, miss, remote, park, unpark, resched)``
    arrays via the one true lowering (``machine.lower_cost``), so the
    engine path can never diverge from the ``run_machine`` path —
    concrete data, ready to stack into a topology batch the jit never
    specializes on."""
    return tuple(np.asarray(x)
                 for x in lower_cost(_resolve_cost(t), n_threads))


def sched_label(s) -> str:
    """Stable display label for a grid's scheduler axis."""
    return schedmod.resolve(s).name


def _lower_sched_host(s, n_threads: int) -> tuple:
    """Lower a scheduler description to host ``(quantum, lhp_quantum,
    cores, jitter)`` scalars — stacked-data siblings of ``_lower_host``
    so the scheduler axis never adds an XLA trace."""
    return tuple(np.asarray(x)
                 for x in schedmod.resolve(s).lower(n_threads))


# --- grid results ------------------------------------------------------------

@dataclass(frozen=True)
class GridCell:
    lock: str
    n_threads: int
    topology: str             # cost_label of the machine
    workload: str             # Workload.name
    result: BenchResult
    scheduler: str = "dedicated"   # sched_label of the OS model


@dataclass(frozen=True)
class GridResult:
    """Flat cell list (threads-major, then workload, then topology) plus
    the number of fresh XLA traces this grid call paid — 0 when every
    (threads, workload) shape was already in the session cache."""
    cells: tuple
    compiles: int

    def __iter__(self):
        return iter(self.cells)

    def __len__(self):
        return len(self.cells)

    def results(self) -> list:
        return [c.result for c in self.cells]

    def cell(self, **want) -> GridCell:
        """The unique cell matching the given field values, e.g.
        ``g.cell(topology="numa2x8", workload="readonly")``."""
        hits = [c for c in self.cells
                if all(getattr(c, k) == v for k, v in want.items())]
        if len(hits) != 1:
            raise KeyError(f"{len(hits)} cells match {want}; have "
                           f"{[(c.n_threads, c.topology, c.scheduler, c.workload) for c in self.cells]}")
        return hits[0]


# --- the session -------------------------------------------------------------

class SimEngine:
    """One lock, many machines: a session holding the compile caches.

    ``lock`` is a registry name (``PROGRAMS``), a spec-builder callable
    with the ``(n_threads, ncs_max=..., cs_shared=...)`` signature (e.g.
    ``functools.partial(compile_spec, my_spec)``), or an already-built
    ``Program`` (then ``workload.ncs_max``/``cs`` are baked in and only
    ``n_steps`` applies). ``topology`` / ``workload`` / ``scheduler`` /
    ``n_threads`` set session defaults; every method takes per-call
    overrides. ``scheduler`` accepts anything ``sched.resolve`` does
    (``Scheduler``, preset name, ``"fair:QxR"`` shorthand, or ``None``
    for the dedicated machine). ``shard`` picks the batch execution
    path (see ``_resolve_shards``): ``"auto"`` (default) splits the
    stacked point axis across devices when more than one is visible and
    is a plain vmap otherwise.
    """

    def __init__(self, lock, *, topology=None, workload=None,
                 scheduler=None, n_threads: int = 8,
                 name: str | None = None, shard="auto"):
        if isinstance(lock, Program):
            self._fixed, self._builder = lock, None
            self.name = name or lock.name
        elif callable(lock):
            self._fixed, self._builder = None, lock
            self.name = name or getattr(lock, "__name__", "lock")
        else:
            from repro.core.locks.programs import PROGRAMS
            self._fixed, self._builder = None, PROGRAMS[lock]
            self.name = name or lock
        self.topology = topology if topology is not None else CostModel()
        self.workload = (resolve_workload(workload) if workload is not None
                         else Workload())
        self.scheduler = schedmod.resolve(scheduler)
        self.n_threads = n_threads
        self.shard = shard
        self._progs: dict = {}
        self._jits: dict = {}
        #: fresh XLA traces this session has paid (trace-time counter)
        self.compiles = 0

    # -- compile caches ------------------------------------------------------
    def program(self, n_threads: int | None = None,
                workload=None) -> Program:
        """The compiled lock program for (threads, workload), cached."""
        T = n_threads or self.n_threads
        wl = (resolve_workload(workload) if workload is not None
              else self.workload)
        if self._fixed is not None:
            return self._fixed
        key = (T, wl.ncs_max, wl.cs_mode)
        prog = self._progs.get(key)
        if prog is None:
            prog = self._progs[key] = self._builder(
                T, ncs_max=wl.ncs_max, cs_shared=wl.cs)
        return prog

    def _runner(self, T: int, wl: Workload, n_points: int,
                n_shards: int = 0):
        """The jitted batched executor for one (threads, workload) shape:
        vmap of the scan engine over ``n_points`` (seed, LoweredCost,
        LoweredSched) triples — wrapped in ``shard_map`` over a 1-D
        device mesh when ``n_shards >= 1``. One XLA trace per cache key,
        counted in ``compiles`` — scheduler scalars are vmapped data,
        never part of the key; the shard count IS part of the key, so
        toggling shard modes never reuses the wrong executable."""
        key = (T, wl.ncs_max, wl.cs_mode, wl.n_steps, n_points, n_shards)
        fn = self._jits.get(key)
        if fn is None:
            prog = self.program(T, wl)

            def go(seeds, hit, miss, remote, park, unpark, resched,
                   quantum, lhp, cores, jitter):
                self.compiles += 1     # runs at trace time only
                _bump_traces()

                def one(seed, h, m, r, p, u, rs, q, lq, co, ji):
                    return run_machine(prog, T, wl.n_steps,
                                       LoweredCost(h, m, r, p, u, rs),
                                       seed,
                                       LoweredSched(q, lq, co, ji))
                batched = jax.vmap(one)
                if n_shards:
                    from repro.sharding.compat import shard_map
                    spec = jax.sharding.PartitionSpec("cells")
                    batched = shard_map(batched, mesh=_mesh(n_shards),
                                        in_specs=spec, out_specs=spec,
                                        check_vma=False)
                return batched(seeds, hit, miss, remote, park,
                               unpark, resched, quantum, lhp,
                               cores, jitter)
            fn = self._jits[key] = jax.jit(go)
        return fn

    def _run_batch(self, seeds, lowered, scheds, wl: Workload, T: int,
                   shard=None):
        """Elementwise batch: ``seeds[i]`` against ``lowered[i]`` under
        ``scheds[i]`` (host-lowered scheduler scalar tuples). When the
        resolved shard count doesn't divide the batch, the batch is
        padded with copies of its last point and the padding trimmed
        from the result — per-point simulations are independent, so
        padding never perturbs real points."""
        k = _resolve_shards(self.shard if shard is None else shard,
                            len(lowered))
        n = len(lowered)
        seeds, lowered, scheds = list(seeds), list(lowered), list(scheds)
        pad = (-n) % k if k else 0
        if pad:
            seeds += [seeds[-1]] * pad
            lowered += [lowered[-1]] * pad
            scheds += [scheds[-1]] * pad
        seeds = jnp.asarray(seeds, jnp.int32)
        stacked = tuple(jnp.asarray(np.stack([lo[i] for lo in lowered]))
                        for i in range(6))
        sstack = tuple(jnp.asarray(np.stack([sc[i] for sc in scheds]))
                       for i in range(4))
        out = self._runner(T, wl, n + pad, k)(seeds, *stacked, *sstack)
        if pad:
            out = jax.tree_util.tree_map(lambda a: a[:n], out)
        return out

    # -- execution -----------------------------------------------------------
    def states(self, seeds, *, topology=None, workload=None,
               scheduler=None, n_threads: int | None = None, shard=None):
        """Raw replica-stacked ``MachineState`` for a seed ensemble on
        one machine (feed to ``summarize_ensemble`` or inspect)."""
        T = n_threads or self.n_threads
        wl = (resolve_workload(workload) if workload is not None
              else self.workload)
        cm = topology if topology is not None else self.topology
        sc = (schedmod.resolve(scheduler) if scheduler is not None
              else self.scheduler)
        seeds = [int(s) for s in seeds]
        low = _lower_host(cm, T)
        slo = _lower_sched_host(sc, T)
        return self._run_batch(seeds, [low] * len(seeds),
                               [slo] * len(seeds), wl, T, shard=shard)

    def run(self, seed: int = 0, **kw) -> BenchResult:
        """One replica, summarized."""
        return self.ensemble([seed], **kw)

    def ensemble(self, seeds, *, topology=None, workload=None,
                 scheduler=None, n_threads: int | None = None) -> BenchResult:
        """Seed ensemble on one machine, aggregated to the paper's
        metrics (one jit per shape, shared with ``grid``)."""
        T = n_threads or self.n_threads
        s = self.states(seeds, topology=topology, workload=workload,
                        scheduler=scheduler, n_threads=T)
        return summarize_ensemble(self.name, T, s)

    def grid(self, *, seeds=(0,), topologies=None, workloads=None,
             schedulers=None, threads=None, shard=None) -> GridResult:
        """Cross product of the seed x topology x scheduler x workload x
        threads axes. Seeds, topologies and schedulers batch into one jit
        per (threads, workload) shape — topologies are stacked
        ``LoweredCost`` data and schedulers stacked ``LoweredSched``
        data, so an SMP box and a 4-node NUMA box under dedicated and
        4x-oversubscribed OS models all share a compile. ``shard``
        overrides the session's batch execution path for this call
        (``False`` = plain vmap, ``True`` = force shard_map, ``"auto"``
        = shard when >1 device; results are bit-identical either way)."""
        seeds = [int(s) for s in seeds]
        topos = [(cost_label(c), _resolve_cost(c))
                 for c in (topologies if topologies is not None
                           else [self.topology])]
        schs = [(sched_label(s), schedmod.resolve(s))
                for s in (schedulers if schedulers is not None
                          else [self.scheduler])]
        wls = [resolve_workload(w) if w is not None else self.workload
               for w in (workloads if workloads is not None
                         else [self.workload])]
        ts = list(threads) if threads is not None else [self.n_threads]
        c0, S = self.compiles, len(seeds)
        cells = []
        for T in ts:
            lows = [(lab, _lower_host(c, T)) for lab, c in topos]
            slos = [(slab, _lower_sched_host(s, T)) for slab, s in schs]
            pairs = [(lab, lo, slab, sl)
                     for lab, lo in lows for slab, sl in slos]
            batch = [lo for _, lo, _, _ in pairs for _ in range(S)]
            sbatch = [sl for _, _, _, sl in pairs for _ in range(S)]
            tiled = [s for _ in pairs for s in seeds]
            for wl in wls:
                st = self._run_batch(tiled, batch, sbatch, wl, T,
                                     shard=shard)
                for p, (lab, _, slab, _) in enumerate(pairs):
                    sl = jax.tree_util.tree_map(
                        lambda a, p=p: a[p * S:(p + 1) * S], st)
                    cells.append(GridCell(
                        lock=self.name, n_threads=T, topology=lab,
                        workload=wl.name, scheduler=slab,
                        result=summarize_ensemble(self.name, T, sl)))
        return GridResult(tuple(cells), self.compiles - c0)


# --- process-wide sessions ---------------------------------------------------

_SESSIONS: dict = {}


def session(lock: str) -> SimEngine:
    """Shared per-lock session (registry names only): suites, the CLI
    and tests reuse one compile cache per lock instead of re-jitting
    per call."""
    eng = _SESSIONS.get(lock)
    if eng is None:
        eng = _SESSIONS[lock] = SimEngine(lock)
    return eng
