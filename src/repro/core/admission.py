"""Admission policies — the paper's segment discipline as a scheduler
primitive.

A lock admission schedule *is* a scheduler (DESIGN.md §L3). This module
factors the paper's arrival-stack / entry-segment mechanism into a queue
abstraction shared by the serving engine:

* ``ReciprocatingQueue`` — O(1) push onto an arrival stack; when the entry
  segment drains, *detach-all* turns the arrival stack into the next entry
  segment. LIFO within a segment, FIFO across segments => thread-specific
  bounded bypass (no starvation), and recently-arrived items are served
  while their cached state is still warm (App. C residency argument).
* ``mitigated`` mode (paper §9.4): serve the entry segment in random order
  *without replacement* — statistically fair long-term, still
  segment-bounded, same aggregate residency benefit.
* ``FifoQueue`` / ``LifoQueue`` baselines (LIFO = unbounded bypass,
  starvation-prone — the foil).
"""
from __future__ import annotations

import random
from collections import deque
from typing import Any, Optional


class AdmissionQueue:
    name = "abstract"

    def push(self, item) -> None:
        raise NotImplementedError

    def pop(self) -> Optional[Any]:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class FifoQueue(AdmissionQueue):
    name = "fifo"

    def __init__(self, seed: int = 0):
        self._q = deque()

    def push(self, item):
        self._q.append(item)

    def pop(self):
        return self._q.popleft() if self._q else None

    def __len__(self):
        return len(self._q)


class LifoQueue(AdmissionQueue):
    name = "lifo"

    def __init__(self, seed: int = 0):
        self._q = []

    def push(self, item):
        self._q.append(item)

    def pop(self):
        return self._q.pop() if self._q else None

    def __len__(self):
        return len(self._q)


class ReciprocatingQueue(AdmissionQueue):
    """The paper's discipline. ``mitigate`` enables §9.4 randomized
    intra-segment order (fairness mitigation, bypass bound preserved)."""
    name = "reciprocating"

    def __init__(self, seed: int = 0, mitigate: bool = False):
        self._arrivals: list = []       # stack (push = the paper's XCHG)
        self._entry: list = []          # detached segment, served from end
        self._rng = random.Random(seed)
        self._mitigate = mitigate
        if mitigate:
            self.name = "reciprocating_mitigated"

    def push(self, item):
        self._arrivals.append(item)

    def pop(self):
        if not self._entry:
            if not self._arrivals:
                return None
            # detach-all: arrivals become the next entry segment
            self._entry = self._arrivals
            self._arrivals = []
        if self._mitigate:
            i = self._rng.randrange(len(self._entry))
            self._entry[i], self._entry[-1] = self._entry[-1], self._entry[i]
        return self._entry.pop()        # LIFO within the segment

    def __len__(self):
        return len(self._arrivals) + len(self._entry)


POLICIES = {
    "fifo": FifoQueue,
    "lifo": LifoQueue,
    "reciprocating": ReciprocatingQueue,
    "reciprocating_mitigated": lambda seed=0: ReciprocatingQueue(
        seed, mitigate=True),
}


def max_bypass_bound(policy: str, population: int) -> float:
    """Worst-case number of times a later arrival can overtake a waiter."""
    if policy == "fifo":
        return 0
    if policy.startswith("reciprocating"):
        return 1                         # paper §2: thread-specific bound
    return float("inf")                  # lifo: unbounded
