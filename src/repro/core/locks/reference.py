"""Reference (oracle) implementations of every lock algorithm.

Each algorithm is written as *generator coroutines*: ``acquire(t)`` /
``release(t, ctx)`` yield atomic memory operations against cells owned by an
interleaving scheduler (``repro.core.sim.interleave``). A yielded op executes
atomically; interleaving happens exactly at yield points, which models a
sequentially-consistent shared memory. These references are:

* the correctness oracle for the vectorized JAX machine (`core/sim`),
* the subject of the hypothesis property tests (mutual exclusion, bounded
  bypass, FIFO-ness, palindromic schedules — paper Table 2),
* line-by-line faithful to the paper's listings (Listing 1 = Reciprocating,
  Listing 7 = Retrograde Ticket, Listing 8 = Gated; plus the MCS / CLH /
  HemLock / Ticket / TAS / TTAS / Anderson baselines it compares against).

Pointer model: per-thread singleton wait elements are identified by
``t + 2`` so that 0 can encode nullptr and 1 can encode LOCKEDEMPTY,
mirroring the paper's low-bit tagging.
"""
from __future__ import annotations

NULL = 0
LOCKEDEMPTY = 1


def eid(t: int) -> int:
    """Wait-element id of thread t (>= 2; 0/1 reserved)."""
    return t + 2


def tid(e: int) -> int:
    return e - 2


class Cell:
    """One shared-memory word (its own cache line; paper aligns to 128B)."""
    __slots__ = ("name", "v")

    def __init__(self, name: str, v: int = 0):
        self.name, self.v = name, v

    def __repr__(self):
        return f"<{self.name}={self.v}>"


class LockAlgorithm:
    """Base: subclasses define acquire/release generators."""
    name = "abstract"
    fifo = False              # strict FIFO admission?
    bounded_bypass = None     # max times a later arrival may overtake, or None

    def __init__(self, n_threads: int):
        self.n = n_threads

    def acquire(self, t: int):
        raise NotImplementedError

    def release(self, t: int, ctx):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Reciprocating Locks — paper Listing 1
# ---------------------------------------------------------------------------
class ReciprocatingLock(LockAlgorithm):
    name = "reciprocating"
    fifo = False
    bounded_bypass = 1        # a later arrival can overtake at most once

    def __init__(self, n):
        super().__init__(n)
        self.arrivals = Cell("Arrivals", NULL)
        self.gate = [Cell(f"Gate[{t}]", NULL) for t in range(n)]

    def acquire(self, t):
        E = eid(t)
        yield ("store", self.gate[t], NULL)              # L17: E.Gate = null
        tail = yield ("xchg", self.arrivals, E, "arrive")   # L20: push
        assert tail != E
        succ, eos = NULL, E                              # L18-19 fast path
        if tail != NULL:                                 # L22: contention
            succ = NULL if tail == LOCKEDEMPTY else tail  # L25: coerce
            assert succ != E
            while True:                                  # L28-32: local spin
                eos = yield ("load", self.gate[t])
                if eos != NULL:
                    break
            assert eos != E
            if succ == eos:                              # L36: terminus?
                succ = NULL                              # L37: quash
                eos = LOCKEDEMPTY                        # L39
        return succ, eos                                 # context -> release

    def release(self, t, ctx):
        succ, eos = ctx
        if succ != NULL:                                 # L53: entry segment
            # L58: enable successor, propagate eos identity
            yield ("store", self.gate[tid(succ)], eos)
            return
        # L64-66: entry+arrivals presumed empty; try uncontended unlock
        assert eos in (LOCKEDEMPTY, eid(t))
        _, ok = yield ("cas", self.arrivals, eos, NULL)
        if ok:
            return
        # L73: new arrivals exist: detach them -> next entry segment
        w = yield ("xchg", self.arrivals, LOCKEDEMPTY)
        assert w not in (NULL, LOCKEDEMPTY, eid(t))
        yield ("store", self.gate[tid(w)], eos)          # L76


# ---------------------------------------------------------------------------
# Reciprocating — "Gated" formulation (paper Listing 8, Appendix H)
# ---------------------------------------------------------------------------
class ReciprocatingGated(LockAlgorithm):
    name = "reciprocating_gated"
    fifo = False
    bounded_bypass = 1

    def __init__(self, n):
        super().__init__(n)
        self.tail = Cell("Tail", NULL)
        self.leader_gate = Cell("LeaderGate", 0)
        self.eos = [Cell(f"eos[{t}]", NULL) for t in range(n)]

    def acquire(self, t):
        E = eid(t)
        yield ("store", self.eos[t], NULL)
        prv = yield ("xchg", self.tail, E, "arrive")
        if prv != NULL:
            while True:                                  # follower: wait eos
                e = yield ("load", self.eos[t])
                if e != NULL:
                    break
            return ("follower", prv, e)
        # leader: wait for previous generation to drain (1v1)
        while True:
            g = yield ("load", self.leader_gate)
            if g == 0:
                break
        yield ("store", self.leader_gate, 1)
        return ("leader", NULL, NULL)

    def release(self, t, ctx):
        role, prv, e = ctx
        if role == "follower":
            if e != prv:
                # systolic relay through the detached segment
                yield ("store", self.eos[tid(prv)], e)
            else:
                yield ("store", self.leader_gate, 0)     # terminus: reopen
            return
        detached = yield ("xchg", self.tail, NULL)
        assert detached != NULL
        if detached != eid(t):
            # zombie: pass &E through the chain as end-of-segment marker
            yield ("store", self.eos[tid(detached)], eid(t))
        else:
            yield ("store", self.leader_gate, 0)


# ---------------------------------------------------------------------------
# Ticket lock + Retrograde Ticket (paper Listing 7, Appendix G)
# ---------------------------------------------------------------------------
class TicketLock(LockAlgorithm):
    name = "ticket"
    fifo = True
    bounded_bypass = 0

    def __init__(self, n):
        super().__init__(n)
        self.ticket = Cell("Ticket", 0)
        self.grant = Cell("Grant", 0)

    def acquire(self, t):
        my = yield ("faa", self.ticket, 1, "arrive")
        while True:
            g = yield ("load", self.grant)
            if g == my:
                break
        return my

    def release(self, t, ctx):
        g = yield ("load", self.grant)
        yield ("store", self.grant, g + 1)


class RetrogradeTicketLock(LockAlgorithm):
    """Mimics Reciprocating admission order with ticket machinery.

    Invariant: Ticket >= Top >= Grant >= Base; tickets in [Base, Top) are the
    entry segment, granted in DESCENDING order; [Top, Ticket) is the arrival
    segment. Top/Base are protected by the lock itself."""
    name = "retrograde"
    fifo = False
    bounded_bypass = 1

    def __init__(self, n):
        super().__init__(n)
        self.ticket = Cell("Ticket", 0)
        self.grant = Cell("Grant", 0)
        self.top = Cell("Top", 0)
        self.base = Cell("Base", 0)

    def acquire(self, t):
        my = yield ("faa", self.ticket, 1, "arrive")
        while True:
            g = yield ("load", self.grant)
            if g == my:
                break
        return my

    def release(self, t, ctx):
        g = (yield ("load", self.grant)) - 1
        base = yield ("load", self.base)
        if g > base:                       # descend through entry segment
            yield ("store", self.grant, g)
            return
        hi = yield ("load", self.top)
        yield ("store", self.base, hi)
        tmp = yield ("load", self.ticket)
        yield ("store", self.top, tmp - 1)
        if tmp == hi + 1:                  # no waiters: unlock
            yield ("store", self.top, tmp)
            yield ("store", self.base, tmp)
            yield ("store", self.grant, tmp)
        else:                              # new entry segment, stay locked
            yield ("store", self.grant, tmp - 1)


# ---------------------------------------------------------------------------
# MCS
# ---------------------------------------------------------------------------
class MCSLock(LockAlgorithm):
    name = "mcs"
    fifo = True
    bounded_bypass = 0

    def __init__(self, n):
        super().__init__(n)
        self.tail = Cell("tail", NULL)
        self.next = [Cell(f"next[{t}]", NULL) for t in range(n)]
        self.locked = [Cell(f"locked[{t}]", 0) for t in range(n)]

    def acquire(self, t):
        yield ("store", self.next[t], NULL)
        yield ("store", self.locked[t], 1)
        pred = yield ("xchg", self.tail, eid(t), "arrive")
        if pred != NULL:
            yield ("store", self.next[tid(pred)], eid(t))
            while True:
                v = yield ("load", self.locked[t])
                if v == 0:
                    break
        return None

    def release(self, t, ctx):
        nxt = yield ("load", self.next[t])
        if nxt == NULL:
            _, ok = yield ("cas", self.tail, eid(t), NULL)
            if ok:
                return
            while True:                      # wait for the linker
                nxt = yield ("load", self.next[t])
                if nxt != NULL:
                    break
        yield ("store", self.locked[tid(nxt)], 0)


# ---------------------------------------------------------------------------
# CLH (Scott Fig. 4.14 standard-interface variant: head field in the lock)
# ---------------------------------------------------------------------------
class CLHLock(LockAlgorithm):
    name = "clh"
    fifo = True
    bounded_bypass = 0

    def __init__(self, n):
        super().__init__(n)
        # n+1 circulating nodes; node n is the initial dummy (flag=0)
        self.flag = [Cell(f"flag[{i}]", 0) for i in range(n + 1)]
        self.tail = Cell("tail", n)          # holds a node INDEX
        self.head = Cell("head", 0)          # owner's node (context passing)
        self.node_of = list(range(n))        # thread -> owned node index

    def acquire(self, t):
        node = self.node_of[t]
        yield ("store", self.flag[node], 1)
        pred = yield ("xchg", self.tail, node, "arrive")
        while True:
            v = yield ("load", self.flag[pred])
            if v == 0:
                break
        yield ("store", self.head, node)
        self.node_of[t] = pred               # adopt predecessor's node
        return None

    def release(self, t, ctx):
        node = yield ("load", self.head)
        yield ("store", self.flag[node], 0)


# ---------------------------------------------------------------------------
# HemLock (with one grant word per thread; address-based transfer)
# ---------------------------------------------------------------------------
class HemLock(LockAlgorithm):
    name = "hemlock"
    fifo = True
    bounded_bypass = 0
    LOCK_ID = 7            # stands for the lock's address

    def __init__(self, n):
        super().__init__(n)
        self.tail = Cell("tail", NULL)
        self.grant = [Cell(f"grant[{t}]", 0) for t in range(n)]

    def acquire(self, t):
        pred = yield ("xchg", self.tail, eid(t), "arrive")
        if pred != NULL:
            p = tid(pred)
            while True:                       # wait for lock's address
                v = yield ("load", self.grant[p])
                if v == self.LOCK_ID:
                    break
            yield ("store", self.grant[p], 0)  # ack: releases pred's element
        return None

    def release(self, t, ctx):
        _, ok = yield ("cas", self.tail, eid(t), NULL)
        if ok:
            return
        yield ("store", self.grant[t], self.LOCK_ID)
        while True:                            # wait for successor's ack
            v = yield ("load", self.grant[t])
            if v == 0:
                break


# ---------------------------------------------------------------------------
# TAS / TTAS / Anderson
# ---------------------------------------------------------------------------
class TASLock(LockAlgorithm):
    name = "tas"

    def __init__(self, n):
        super().__init__(n)
        self.word = Cell("lock", 0)

    def acquire(self, t):
        yield ("arrive",)
        while True:
            v = yield ("xchg", self.word, 1)
            if v == 0:
                return None

    def release(self, t, ctx):
        yield ("store", self.word, 0)


class TTASLock(LockAlgorithm):
    name = "ttas"

    def __init__(self, n):
        super().__init__(n)
        self.word = Cell("lock", 0)

    def acquire(self, t):
        yield ("arrive",)
        while True:
            v = yield ("load", self.word)
            if v == 0:
                v = yield ("xchg", self.word, 1)
                if v == 0:
                    return None

    def release(self, t, ctx):
        yield ("store", self.word, 0)


class AndersonLock(LockAlgorithm):
    """Array-based queue lock: T*L space (the paper's space-complexity foil)."""
    name = "anderson"
    fifo = True
    bounded_bypass = 0

    def __init__(self, n):
        super().__init__(n)
        self.slots = [Cell(f"slot[{i}]", 1 if i == 0 else 0)
                      for i in range(n)]
        self.nxt = Cell("next", 0)

    def acquire(self, t):
        my = (yield ("faa", self.nxt, 1, "arrive")) % self.n
        while True:
            v = yield ("load", self.slots[my])
            if v == 1:
                break
        yield ("store", self.slots[my], 0)
        return my

    def release(self, t, ctx):
        yield ("store", self.slots[(ctx + 1) % self.n], 1)


ALGORITHMS = {
    c.name: c for c in (
        ReciprocatingLock, ReciprocatingGated, TicketLock,
        RetrogradeTicketLock, MCSLock, CLHLock, HemLock, TASLock, TTASLock,
        AndersonLock,
    )
}
