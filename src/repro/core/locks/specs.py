"""The lock zoo, authored as declarative ``LockSpec`` phase specs.

Every lock is a spec function ``def name(s): ...`` declaring memory
regions, symbolic registers and labelled steps in the four phases
(``doorway`` / ``waiting`` / ``entry`` / ``release`` — see
``core/locks/dsl.py``); ``core/locks/compile.py`` lowers it to the
``Program`` handler-table form and injects the shared NCS/CS scaffolding.
Op semantics and result encodings (CAS ``old * 2 + ok``, SPIN blocking,
PARK_EQ costs, LOCKEDEMPTY == 1) are the contract table at the top of
``core/sim/machine.py``.

Paper roster (each compiles to byte-identical metrics vs the pre-DSL
hand-rolled tables — asserted by ``tests/test_lock_dsl.py``):
``reciprocating`` (Listing 1), ``retrograde`` ticket (Listing 7),
``ticket``, ``mcs``, ``clh``, ``hemlock``, ``ttas``, ``anderson``.

Extended roster (the follow-up papers the DSL makes cheap to express —
PAPERS.md): ``hapax`` (value-based FIFO admission), ``fissile`` (TS fast
path grafted onto a queue slow path), ``spin_then_park`` (bounded spin,
then park/unpark under the machine's park cost model).

Abortable roster (the hostile-OS layer — timed waits via the DSL's
``abort`` phase and the ``PARK_*_TIMEOUT`` ops): ``reciprocating_abortable``
(true abort: a CAS-consumed grant *baton* over ticket-tagged cells, so an
impatient waiter withdraws by publishing an abort marker the release walk
reclaims) and ``mcs_timeout`` (relay abort, AQS-style: a timed-out waiter
keeps its queue node and, once granted, forwards the handoff through the
release chain without entering the CS).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.locks.dsl import (
    CAS, DELAY, FAA, LOAD, LOCKEDEMPTY, NCS, NOP, PARK_EQ, PARK_EQ_TIMEOUT,
    SPIN_EQ, SPIN_NE, STORE, XCHG,
)


# ---------------------------------------------------------------------------
# Reciprocating (paper Listing 1)
# ---------------------------------------------------------------------------
def reciprocating(s):
    """Arrival stack + detached entry segment: XCHG push in the doorway,
    local spin on the own element, handoff (or segment close) on release."""
    arrivals = s.word("arrivals")
    elem = s.per_thread("element")
    s.regs("succ", "eos")
    s.expect(doorway="constant", release="wait_free", spin="own",
             footprint=1, bypass=2)

    @s.step("doorway")
    def prepare(c):                         # E = 0 (clean wait element)
        return c.op(STORE(elem.at(c.t), 0))

    @s.step("doorway")
    def push(c):                            # push E onto the arrival stack
        return c.op(XCHG(arrivals, elem.at(c.t)))

    @s.step("doorway")
    def consume_tail(c):                    # doorway: inspect the old tail
        E = elem.at(c.t)
        uncont = c.res == 0
        succ = jnp.where(c.res <= 1, 0, c.res)      # coerce LOCKEDEMPTY
        c.r.succ = jnp.where(uncont, 0, succ)
        c.r.eos = jnp.where(uncont, E, 0)
        return c.when(uncont, c.enter_cs(admit=True),
                      c.op(SPIN_NE(E, 0), to="woke"), arrive=True)

    @s.step("waiting")
    def woke(c):                            # res = eos value from the gate
        succ = c.r.succ
        term = succ == c.res                # terminus sentinel?
        c.r.succ = jnp.where(term, 0, succ)
        c.r.eos = jnp.where(term, LOCKEDEMPTY, c.res)
        return c.enter_cs(admit=True)

    @s.step("release")
    def handoff(c):                         # pass eos to succ, or close
        succ, eos = c.r.succ, c.r.eos
        has_succ = succ != 0
        return c.when(has_succ, c.op(STORE(succ, eos), to=NCS),
                      c.op(CAS(arrivals, eos, 0)))

    @s.step("release")
    def close(c):                           # res = CAS old*2+ok
        ok = (c.res % 2) == 1
        return c.when(ok, c.op(NOP(), to=NCS),
                      c.op(XCHG(arrivals, LOCKEDEMPTY)))

    @s.step("release")
    def detach(c):                          # res = detached head element
        return c.op(STORE(c.res, c.r.eos), to=NCS)


# ---------------------------------------------------------------------------
# Ticket lock
# ---------------------------------------------------------------------------
def ticket(s):
    """FIFO by FAA ticket; global spin on the grant word (the Fig. 1
    collapse case)."""
    tk, gr = s.word("ticket"), s.word("grant")
    s.regs("my")
    s.expect(doorway="constant", release="wait_free", spin="shared",
             footprint=0, bypass=1)

    @s.step("doorway")
    def take(c):
        return c.op(FAA(tk, 1))

    @s.step("doorway")
    def got(c):
        c.r.my = c.res
        return c.op(SPIN_EQ(gr, c.res), arrive=True)

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def load_grant(c):
        return c.op(LOAD(gr))

    @s.step("release")
    def bump_grant(c):
        return c.op(STORE(gr, c.res + 1), to=NCS)


# ---------------------------------------------------------------------------
# Retrograde ticket (paper Listing 7)
# ---------------------------------------------------------------------------
def retrograde(s):
    """Ticket lock whose release walks the grant *backwards* through the
    entry segment — mimics reciprocating admission on ticket state."""
    tk, gr = s.word("ticket"), s.word("grant")
    top, bs = s.word("top"), s.word("base")
    s.regs("my", "g", "hi", "tmp")
    s.expect(doorway="constant", release="wait_free", spin="shared",
             footprint=0, bypass=2)

    @s.step("doorway")
    def take(c):
        return c.op(FAA(tk, 1))

    @s.step("doorway")
    def got(c):
        c.r.my = c.res
        return c.op(SPIN_EQ(gr, c.res), arrive=True)

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def load_grant(c):
        return c.op(LOAD(gr))

    @s.step("release")
    def load_base(c):
        c.r.g = c.res - 1
        return c.op(LOAD(bs))

    @s.step("release")
    def descend_or_flip(c):                 # res = base of entry segment
        desc = c.r.g > c.res                # still inside the segment
        return c.when(desc, c.op(STORE(gr, c.r.g), to=NCS),
                      c.op(LOAD(top)))

    @s.step("release")
    def read_top(c):                        # res = segment top
        c.r.hi = c.res
        return c.op(STORE(bs, c.res))

    @s.step("release")
    def read_ticket(c):
        return c.op(LOAD(tk))

    @s.step("release")
    def stage_top(c):                       # res = current ticket
        c.r.tmp = c.res
        return c.op(STORE(top, c.res - 1))

    @s.step("release")
    def flip(c):
        empty = c.r.tmp == c.r.hi + 1       # no waiters
        return c.when(empty, c.op(STORE(top, c.r.tmp)),
                      c.op(STORE(gr, c.r.tmp - 1), to=NCS))

    @s.step("release")
    def reset_base(c):
        return c.op(STORE(bs, c.r.tmp))

    @s.step("release")
    def reset_grant(c):
        return c.op(STORE(gr, c.r.tmp), to=NCS)


# ---------------------------------------------------------------------------
# MCS
# ---------------------------------------------------------------------------
def mcs(s):
    """Queue lock: swap onto the tail, link behind the predecessor, local
    spin on the own ``locked`` flag."""
    tail = s.word("tail")
    nxt = s.per_thread("next")
    lck = s.per_thread("locked")
    s.expect(doorway="constant", release="waits", spin="own",
             footprint=2, bypass=1)

    @s.step("doorway")
    def clear_next(c):
        return c.op(STORE(nxt.at(c.t), 0))

    @s.step("doorway")
    def set_locked(c):
        return c.op(STORE(lck.at(c.t), 1))

    @s.step("doorway")
    def swap_tail(c):
        return c.op(XCHG(tail, nxt.at(c.t)))

    @s.step("doorway")
    def link(c):                            # res = predecessor (old tail)
        uncont = c.res == 0
        return c.when(uncont, c.enter_cs(admit=True),
                      c.op(STORE(c.res, nxt.at(c.t))), arrive=True)

    @s.step("waiting")
    def wait_grant(c):
        return c.op(SPIN_EQ(lck.at(c.t), 0))

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def read_next(c):
        return c.op(LOAD(nxt.at(c.t)))

    @s.step("release")
    def pass_or_close(c):                   # res = successor next-addr
        has = c.res != 0
        return c.when(has, c.op(STORE(lck.translate(c.res, nxt), 0), to=NCS),
                      c.op(CAS(tail, nxt.at(c.t), 0)))

    @s.step("release")
    def cas_done(c):                        # res = CAS old*2+ok
        ok = (c.res % 2) == 1
        return c.when(ok, c.op(NOP(), to=NCS),
                      c.op(SPIN_NE(nxt.at(c.t), 0)))

    @s.step("release")
    def wake_late(c):                       # res = late successor next-addr
        return c.op(STORE(lck.translate(c.res, nxt), 0), to=NCS)


# ---------------------------------------------------------------------------
# CLH (Scott 4.14) — nodes circulate; T+1 nodes, tail starts at the dummy
# ---------------------------------------------------------------------------
def clh(s):
    """Implicit queue: spin on the *predecessor's* node. Nodes circulate,
    so static NUMA homes go stale over time — exactly the paper's point."""
    node = s.per_thread("node")
    dummy = s.array("dummy", 1)
    tail = s.word("tail", init=dummy.base)
    head = s.word("head")
    s.regs("mynode", "pred")
    s.expect(doorway="constant", release="wait_free", spin="cell",
             footprint=1, bypass=1)

    @s.step("doorway")
    def claim(c):                           # lazy first-episode node init
        mynode = jnp.where(c.r.mynode == 0, node.at(c.t), c.r.mynode)
        c.r.mynode = mynode
        return c.op(STORE(mynode, 1))

    @s.step("doorway")
    def swap_tail(c):
        return c.op(XCHG(tail, c.r.mynode))

    @s.step("doorway")
    def watch_pred(c):                      # res = predecessor node
        c.r.pred = c.res
        return c.op(SPIN_EQ(c.res, 0), arrive=True)

    @s.step("waiting")
    def publish_head(c):
        return c.op(STORE(head, c.r.mynode))

    @s.step("entry")
    def adopt(c):                           # recycle the pred's node
        c.r.mynode = c.r.pred
        return c.enter_cs(admit=True)

    @s.step("release")
    def load_head(c):
        return c.op(LOAD(head))

    @s.step("release")
    def clear_flag(c):                      # res = head node addr
        return c.op(STORE(c.res, 0), to=NCS)


# ---------------------------------------------------------------------------
# HemLock — CTR-style: grant word doubles as the queue link
# ---------------------------------------------------------------------------
def hemlock(s):
    """Tail swap like MCS, but the successor acknowledges the handoff by
    clearing the *predecessor's* grant word (no queue nodes)."""
    LOCK_ID = 5     # sentinel *value* written into a grant word
    tail = s.word("tail")
    grant = s.per_thread("grant")
    s.regs("pred")
    s.expect(doorway="constant", release="waits", spin="cell",
             footprint=1, bypass=1)

    @s.step("doorway")
    def swap_tail(c):
        return c.op(XCHG(tail, grant.at(c.t)))

    @s.step("doorway")
    def check(c):                           # res = predecessor grant addr
        uncont = c.res == 0
        c.r.pred = c.res
        return c.when(uncont, c.enter_cs(admit=True),
                      c.op(SPIN_EQ(c.res, LOCK_ID)), arrive=True)

    @s.step("waiting")
    def ack(c):                             # grant[pred] = 0 (consume)
        return c.op(STORE(c.r.pred, 0))

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def try_close(c):
        return c.op(CAS(tail, grant.at(c.t), 0))

    @s.step("release")
    def closed(c):                          # res = CAS old*2+ok
        ok = (c.res % 2) == 1
        return c.when(ok, c.op(NOP(), to=NCS),
                      c.op(STORE(grant.at(c.t), LOCK_ID)))

    @s.step("release")
    def wait_ack(c):
        return c.op(SPIN_EQ(grant.at(c.t), 0), to=NCS)


# ---------------------------------------------------------------------------
# TTAS (polite test-and-test-and-set) — no doorway: not FCFS
# ---------------------------------------------------------------------------
def ttas(s):
    """Global spinning on one flag word; every handoff is a broadcast
    invalidation storm (the other Fig. 1 collapse case)."""
    flag = s.word("flag")
    s.expect(doorway="none", release="wait_free", spin="shared",
             footprint=0, bypass=None)

    @s.step("waiting")
    def wait_free(c):
        return c.op(SPIN_EQ(flag, 0), arrive=True)

    @s.step("entry")
    def grab(c):
        return c.op(XCHG(flag, 1))

    @s.step("entry")
    def check(c):                           # res = old flag value
        got = c.res == 0
        return c.when(got, c.enter_cs(admit=True),
                      c.op(SPIN_EQ(flag, 0), to="grab"))

    @s.step("release")
    def unlock(c):
        return c.op(STORE(flag, 0), to=NCS)


# ---------------------------------------------------------------------------
# Anderson array lock
# ---------------------------------------------------------------------------
def anderson(s):
    """FIFO by FAA over an array of spin slots (flag-based; contrast with
    ``hapax``'s value-based cells)."""
    nxt = s.word("next_slot")
    slots = s.array("slots", s.T, init={0: 1})
    s.regs("slot")
    s.expect(doorway="constant", release="wait_free", spin="cell",
             footprint=0, bypass=1)

    @s.step("doorway")
    def take(c):
        return c.op(FAA(nxt, 1))

    @s.step("doorway")
    def watch(c):                           # res = my slot index (ticket)
        slot = slots.at(c.res % s.T)
        c.r.slot = slot
        return c.op(SPIN_EQ(slot, 1), arrive=True)

    @s.step("waiting")
    def consume(c):                         # reset my slot for reuse
        return c.op(STORE(c.r.slot, 0))

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def grant_next(c):
        here = c.r.slot - slots.base
        return c.op(STORE(slots.at((here + 1) % s.T), 1), to=NCS)


# ---------------------------------------------------------------------------
# Hapax — value-based FIFO admission (extended roster, PAPERS.md)
# ---------------------------------------------------------------------------
def hapax(s):
    """Hapax-style value-based mutual exclusion (Dice & Kogan): FIFO
    admission decided by *values*, constant-time arrival and release.

    Ticket k waits until cell ``k mod T`` *holds the value k*; release of
    k publishes ``k+1`` into the successor cell. Values increase
    monotonically, so a stale cell can never falsely admit — the ABA
    hazard that forces flag-based array locks (``anderson``) to consume
    and reset their slots disappears, and release is a single store.
    (Sim-level embodiment of the value-based idea, not the paper's exact
    word layout.)"""
    tk = s.word("ticket")
    cells = s.array("cells", s.T)
    s.regs("my")
    s.expect(doorway="constant", release="wait_free", spin="cell",
             footprint=0, bypass=1)

    @s.step("doorway")
    def take(c):
        return c.op(FAA(tk, 1))

    @s.step("doorway")
    def watch(c):                           # res = my ticket value
        c.r.my = c.res
        return c.op(SPIN_EQ(cells.at(c.res % s.T), c.res), arrive=True)

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def publish(c):
        nxt = c.r.my + 1
        return c.op(STORE(cells.at(nxt % s.T), nxt), to=NCS)


# ---------------------------------------------------------------------------
# Fissile — TS fast path over a queue slow path (extended roster)
# ---------------------------------------------------------------------------
def fissile(s):
    """Fissile-style composite lock (Dice & Kogan): an uncontended
    test-and-set fast path grafted onto a FIFO queue slow path.

    Arrivals first try one XCHG on the fast word; on failure they take a
    ticket and wait in value-based FIFO order (as ``hapax``), and *only
    the queue head* competes with barging fast-path arrivals for the fast
    word — competition for the TS word stays O(1) while the queue absorbs
    the rest. Release is a single store for both paths."""
    fast = s.word("fast")
    tk = s.word("ticket")
    cells = s.array("cells", s.T)
    s.regs("my")
    s.expect(doorway="constant", release="wait_free", spin="shared",
             footprint=0, bypass=None)

    @s.step("doorway")
    def try_fast(c):
        return c.op(XCHG(fast, 1))

    @s.step("doorway")
    def check_fast(c):                      # res = old fast word
        got = c.res == 0
        return c.when(got, c.enter_cs(admit=True),
                      c.op(FAA(tk, 1)), arrive=True)

    @s.step("waiting")
    def join_queue(c):                      # res = my ticket value
        c.r.my = c.res
        return c.op(SPIN_EQ(cells.at(c.res % s.T), c.res))

    @s.step("waiting")
    def head_grab(c):                       # queue head: contend for fast
        return c.op(XCHG(fast, 1))

    @s.step("waiting")
    def head_check(c):                      # res = old fast word
        got = c.res == 0
        nxt = c.r.my + 1
        return c.when(got, c.op(STORE(cells.at(nxt % s.T), nxt)),
                      c.op(DELAY(8), to="head_grab"))

    @s.step("entry")
    def pass_baton(c):                      # successor advances to head
        return c.enter_cs(admit=True)

    @s.step("release")
    def unlock(c):
        return c.op(STORE(fast, 0), to=NCS)


# ---------------------------------------------------------------------------
# Spin-then-park — MCS waiting with a bounded spin, then PARK (extended)
# ---------------------------------------------------------------------------
def spin_then_park(s):
    """MCS queue with the classic engineering compromise in the waiting
    phase: probe the grant flag a few times (fast handoff while the CS is
    short), then *park*. Park/unpark latencies are charged by the
    machine's cost model (``CostModel.park_cost`` / ``unpark_cost`` — the
    PARK_EQ row of the machine.py contract table), so the throughput cost
    of parking is measurable, not assumed."""
    SPIN_BUDGET = 4     # probes before giving up and parking
    BACKOFF = 6         # private cycles between probes
    tail = s.word("tail")
    nxt = s.per_thread("next")
    lck = s.per_thread("locked")
    s.regs("spins")
    s.expect(doorway="constant", release="waits", spin="own",
             footprint=2, bypass=1)

    @s.step("doorway")
    def clear_next(c):
        return c.op(STORE(nxt.at(c.t), 0))

    @s.step("doorway")
    def set_locked(c):
        return c.op(STORE(lck.at(c.t), 1))

    @s.step("doorway")
    def swap_tail(c):
        return c.op(XCHG(tail, nxt.at(c.t)))

    @s.step("doorway")
    def link(c):                            # res = predecessor (old tail)
        uncont = c.res == 0
        c.r.spins = SPIN_BUDGET
        return c.when(uncont, c.enter_cs(admit=True),
                      c.op(STORE(c.res, nxt.at(c.t))), arrive=True)

    @s.step("waiting")
    def probe(c):
        return c.op(LOAD(lck.at(c.t)))

    @s.step("waiting")
    def probe_check(c):                     # res = my locked flag
        free = c.res == 0
        c.r.spins = c.r.spins - 1
        exhausted = c.r.spins <= 0
        park = c.op(PARK_EQ(lck.at(c.t), 0), to="granted")
        spin_more = c.op(DELAY(BACKOFF), to="probe")
        return c.when(free, c.enter_cs(admit=True),
                      c.when(exhausted, park, spin_more))

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def read_next(c):
        return c.op(LOAD(nxt.at(c.t)))

    @s.step("release")
    def pass_or_close(c):                   # res = successor next-addr
        has = c.res != 0
        return c.when(has, c.op(STORE(lck.translate(c.res, nxt), 0), to=NCS),
                      c.op(CAS(tail, nxt.at(c.t), 0)))

    @s.step("release")
    def cas_done(c):                        # res = CAS old*2+ok
        ok = (c.res % 2) == 1
        return c.when(ok, c.op(NOP(), to=NCS),
                      c.op(SPIN_NE(nxt.at(c.t), 0)))

    @s.step("release")
    def wake_late(c):                       # res = late successor next-addr
        return c.op(STORE(lck.translate(c.res, nxt), 0), to=NCS)


# ---------------------------------------------------------------------------
# Reciprocating-abortable — true abort over ticket-tagged grant batons
# ---------------------------------------------------------------------------
def reciprocating_abortable(s):
    """Retrograde (reciprocating-admission) ticket lock with *true abort*.

    Grants travel as a **baton**: releasing ticket g's holder XCHGs the
    tag ``g*4+1`` into cell ``g mod T``; admission is an atomic
    CAS-consume of a baton (tag -> 0), so at most one baton exists and
    mutual exclusion reduces to CAS atomicity. Ticket-unique tags make
    cell reuse ABA-safe without generation counters.

    An impatient waiter (timed park exhausted) withdraws by CASing the
    abort marker ``my*4+2`` into its cell — never over a live baton: a
    baton found while probing is the lock itself and is consumed
    instead (ghost batons of aborted residue-mates are reclaimed the
    same way, which is what keeps the lock live when a marker was
    displaced). The release walk, on finding its grant displaced an
    abort marker, retracts the just-published baton by CAS and walks on
    to the next ticket — unless the retract loses, which means a prober
    already consumed the baton and the handoff is complete."""
    PATIENCE = 1200     # private cycles per timed-park round
    ROUNDS = 4          # park rounds before withdrawing
    tk = s.word("ticket")
    gr = s.word("grant")
    top = s.word("top")
    bs = s.word("base")
    cells = s.array("cells", s.T, init={0: 1})   # baton for ticket 0
    s.regs("my", "tries", "g", "hi", "tmp")
    # The release walk retracts ghost batons in a loop (retract ->
    # load_base) — the declared opt-out the gate's safety floor points at.
    s.expect(doorway="constant", release="unbounded", spin="cell",
             footprint=0, bypass=2)

    def park(c, to="round"):
        return c.op(PARK_EQ_TIMEOUT(cells.at(c.r.my % s.T),
                                    c.r.my * 4 + 1, PATIENCE), to=to)

    @s.step("doorway")
    def take(c):
        return c.op(FAA(tk, 1))

    @s.step("doorway")
    def got(c):                             # res = my ticket
        c.r.my = c.res
        c.r.tries = ROUNDS
        return c.op(PARK_EQ_TIMEOUT(cells.at(c.res % s.T), c.res * 4 + 1,
                                    PATIENCE), to="round", arrive=True)

    @s.step("waiting")
    def round(c):                           # res = cell*2 + ok
        ok = (c.res % 2) == 1
        mine = cells.at(c.r.my % s.T)
        return c.when(ok, c.op(CAS(mine, c.r.my * 4 + 1, 0), to="consume"),
                      c.op(LOAD(mine), to="probe"))

    @s.step("waiting")
    def consume(c):                         # res = old*2 + ok
        ok = (c.res % 2) == 1
        # lost the baton to a ghost-reclaiming residue mate: wait again
        return c.when(ok, c.enter_cs(admit=True), park(c))

    @s.step("abort")
    def probe(c):                           # res = cell value (timed out)
        v = c.res
        mine = cells.at(c.r.my % s.T)
        is_baton = (v % 4) == 1             # a grant tag — mine or a ghost
        c.r.tries = c.r.tries - 1
        exhausted = c.r.tries <= 0
        take_baton = c.op(CAS(mine, v, 0), to="reclaim")
        withdraw = c.op(CAS(mine, v, c.r.my * 4 + 2), to="abort_done")
        return c.when(is_baton, take_baton,
                      c.when(exhausted, withdraw, park(c)))

    @s.step("abort")
    def reclaim(c):                         # res = old*2 + ok
        ok = (c.res % 2) == 1
        return c.when(ok, c.enter_cs(admit=True), park(c))

    @s.step("abort")
    def abort_done(c):                      # res = old*2 + ok
        ok = (c.res % 2) == 1
        # marker placed: episode abandoned (no admit). A failed CAS
        # means the cell changed under us — re-examine before leaving.
        return c.when(ok, c.op(NOP(), to=NCS), park(c))

    @s.step("release")
    def load_grant(c):
        return c.op(LOAD(gr))

    @s.step("release")
    def load_base(c):                       # res = granted ticket
        c.r.g = c.res - 1
        return c.op(LOAD(bs))

    @s.step("release")
    def descend_or_flip(c):                 # res = segment base
        desc = c.r.g > c.res
        return c.when(desc, c.op(STORE(gr, c.r.g), to="publish"),
                      c.op(LOAD(top), to="read_top"))

    @s.step("release")
    def publish(c):                         # baton for ticket g
        g = c.r.g
        return c.op(XCHG(cells.at(g % s.T), g * 4 + 1))

    @s.step("release")
    def delivered(c):                       # res = displaced cell value
        aborted = c.res == c.r.g * 4 + 2
        return c.when(aborted,
                      c.op(CAS(cells.at(c.r.g % s.T), c.r.g * 4 + 1, 0),
                           to="retract"),
                      c.op(NOP(), to=NCS))

    @s.step("release")
    def retract(c):                         # res = old*2 + ok
        ok = (c.res % 2) == 1
        # retracted the ghost baton: reclaim g, walk on to the next
        # ticket; a lost CAS means a prober consumed it — handoff done
        return c.when(ok, c.op(LOAD(gr), to="load_base"),
                      c.op(NOP(), to=NCS))

    @s.step("release")
    def read_top(c):                        # res = segment top
        c.r.hi = c.res
        return c.op(STORE(bs, c.res))

    @s.step("release")
    def read_ticket(c):
        return c.op(LOAD(tk))

    @s.step("release")
    def stage_top(c):                       # res = current ticket
        c.r.tmp = c.res
        return c.op(STORE(top, c.res - 1))

    @s.step("release")
    def flip(c):
        empty = c.r.tmp == c.r.hi + 1       # no waiters
        c.r.g = jnp.where(empty, c.r.tmp, c.r.tmp - 1)
        return c.when(empty, c.op(STORE(top, c.r.tmp)),
                      c.op(STORE(gr, c.r.tmp - 1), to="publish"))

    @s.step("release")
    def reset_base(c):
        return c.op(STORE(bs, c.r.tmp))

    @s.step("release")
    def reset_grant(c):                     # pre-grant the next ticket
        return c.op(STORE(gr, c.r.tmp), to="publish")


# ---------------------------------------------------------------------------
# MCS-timeout — relay abort (AQS-style lazy cancellation)
# ---------------------------------------------------------------------------
def mcs_timeout(s):
    """MCS whose waiters time out into *relay* mode: the impatient waiter
    abandons its CS claim but keeps its queue node (unlinking a middle
    node needs neighbour coordination — the AQS/lazy-abort compromise);
    once the grant arrives it forwards the handoff straight through the
    shared release chain without entering the critical section. Queue
    integrity is preserved by construction; the cost is that an aborted
    waiter is only *logically* gone until its grant shows up."""
    PATIENCE = 1600     # private cycles per timed-park round
    ROUNDS = 3          # park rounds before giving up the claim
    tail = s.word("tail")
    nxt = s.per_thread("next")
    lck = s.per_thread("locked")
    s.regs("tries")
    s.expect(doorway="constant", release="waits", spin="own",
             footprint=2, bypass=1)

    @s.step("doorway")
    def clear_next(c):
        return c.op(STORE(nxt.at(c.t), 0))

    @s.step("doorway")
    def set_locked(c):
        return c.op(STORE(lck.at(c.t), 1))

    @s.step("doorway")
    def swap_tail(c):
        return c.op(XCHG(tail, nxt.at(c.t)))

    @s.step("doorway")
    def link(c):                            # res = predecessor (old tail)
        uncont = c.res == 0
        c.r.tries = ROUNDS
        return c.when(uncont, c.enter_cs(admit=True),
                      c.op(STORE(c.res, nxt.at(c.t))), arrive=True)

    @s.step("waiting")
    def wait_grant(c):
        return c.op(PARK_EQ_TIMEOUT(lck.at(c.t), 0, PATIENCE))

    @s.step("waiting")
    def check_grant(c):                     # res = lck*2 + ok
        ok = (c.res % 2) == 1
        c.r.tries = c.r.tries - 1
        patient = c.r.tries > 0
        return c.when(ok, c.enter_cs(admit=True),
                      c.when(patient,
                             c.op(PARK_EQ_TIMEOUT(lck.at(c.t), 0, PATIENCE),
                                  to="check_grant"),
                             c.op(PARK_EQ(lck.at(c.t), 0), to="relay")))

    @s.step("abort")
    def relay(c):
        # granted after giving up: skip the CS, relay the handoff
        return c.op(LOAD(nxt.at(c.t)), to="pass_or_close")

    @s.step("release")
    def read_next(c):
        return c.op(LOAD(nxt.at(c.t)))

    @s.step("release")
    def pass_or_close(c):                   # res = successor next-addr
        has = c.res != 0
        return c.when(has, c.op(STORE(lck.translate(c.res, nxt), 0), to=NCS),
                      c.op(CAS(tail, nxt.at(c.t), 0)))

    @s.step("release")
    def cas_done(c):                        # res = CAS old*2+ok
        ok = (c.res % 2) == 1
        return c.when(ok, c.op(NOP(), to=NCS),
                      c.op(SPIN_NE(nxt.at(c.t), 0)))

    @s.step("release")
    def wake_late(c):                       # res = late successor next-addr
        return c.op(STORE(lck.translate(c.res, nxt), 0), to=NCS)


#: The full roster: paper locks first (spec-for-spec equal to the frozen
#: pre-DSL tables), then the extended variants the DSL made cheap.
SPECS = {
    "reciprocating": reciprocating,
    "ticket": ticket,
    "retrograde": retrograde,
    "mcs": mcs,
    "clh": clh,
    "hemlock": hemlock,
    "ttas": ttas,
    "anderson": anderson,
    "hapax": hapax,
    "fissile": fissile,
    "spin_then_park": spin_then_park,
    "reciprocating_abortable": reciprocating_abortable,
    "mcs_timeout": mcs_timeout,
}

#: Variants added on top of the paper's roster (the `locks-ext` suite).
NEW_VARIANTS = ("hapax", "fissile", "spin_then_park")

#: Abortable/timeout variants (the `hostile` suite): locks whose specs
#: use the DSL ``abort`` phase and the timed-park ops.
ABORTABLE_VARIANTS = ("reciprocating_abortable", "mcs_timeout")
