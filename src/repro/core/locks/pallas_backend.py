"""Backend #2: lower a ``LockIR`` to a Pallas kernel — the *measured* tier.

Where the sim backend (``ir.to_sim_program`` + ``core/sim/machine.py``)
*models* time — every micro-op is priced by a ``CostModel`` and the bus
serializes line transfers — this backend *spends* it: the same IR
handler table runs as a ``pl.pallas_call`` kernel in which each thread
is a grid program hammering the lock words through the device atomics
layer (``core/runtime/atomics.py``), and throughput is wall-clock
episodes per second.

Execution model
---------------
The kernel runs on a ``grid = (rounds, T)``: grid iteration is
row-major, so one *round* gives every thread one micro-op slice in
thread order — a deterministic round-robin schedule at op granularity
(the schedule the backend-agreement differential in
``tests/test_ir_backends.py`` replays through the sim machine with a
uniform cost model). A slice is exactly one turn of the machine's
op/handler crank:

1. execute the thread's pending op against shared memory via the
   ``Atomics`` layer (one generic read-modify-write per the
   ``ir.OP_TABLE`` contract),
2. unsatisfied waits (SPIN/PARK) retry next round — no transition;
   timed parks burn a probe budget and complete with ``ok == 0``,
3. otherwise dispatch the per-pc handler (``lax.switch`` over the IR's
   handler closures — the same closures the sim runs) and commit the
   transition: registers, next pc, next op, rng.

Lock state, per-thread machine state, and the metrics (episodes,
admission ring, arrive/admit latency in slices, the mutual-exclusion
guard/collision counter) all live in aliased output refs, so state
persists across the whole grid and the kernel is a single device
launch.

Modes
-----
``interpret=True`` (default on CPU) runs the identical kernel through
the Pallas interpreter — grid programs execute sequentially, so the
emulated read-modify-writes are linearizable and CI can run the
measured tier everywhere. On a real accelerator the atomics layer
switches to ``pl.atomic_*`` / guard-lock splices. ``backends()``
probes what this process can actually run (the ``repro.bench list
--backends`` catalogue).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import numpy as np

from repro.core.locks.ir import LockIR, lower_spec
from repro.core.runtime.atomics import PallasAtomics
from repro.core.sim import machine as M

__all__ = ["MeasuredResult", "run_measured", "backends", "resolve_ir",
           "ADM_LOG_M", "GUARD_WORD"]

#: admission-ring capacity (slot ADM_LOG_M is the overflow spill slot)
ADM_LOG_M = 256
#: reserved word for the device-mode atomics guard: every spec's layout
#: keeps words 6..7 unused (lock words 0..3, CS words 4..5, arrays >= 8)
GUARD_WORD = 6


@dataclass
class MeasuredResult:
    """One measured run: paper metrics in wall-clock/slice units."""
    name: str
    n_threads: int
    rounds: int
    backend: str                 # "pallas-interpret" | "pallas-device"
    episodes: int                # total CS admissions
    per_thread: np.ndarray       # (T,) episodes per thread
    collisions: int              # ME violations observed (must be 0)
    admissions: np.ndarray       # (ADM_LOG_M,) ring of admitted tids
    admission_counts: int        # total admissions (ring position)
    returns: int                 # NCS returns (returns - episodes = aborts)
    wall_s: float                # wall time of the warm timed launch
    compile_s: float             # first-launch (trace+compile) time

    @property
    def slices(self) -> int:
        return self.rounds * self.n_threads

    @property
    def throughput_eps(self) -> float:
        """Episodes per wall-second — the measured analogue of the sim's
        episodes-per-kilocycle."""
        return self.episodes / max(self.wall_s, 1e-9)

    @property
    def episodes_per_kslice(self) -> float:
        """Wall-free progress rate: episodes per 1000 op slices (the
        schedule-normalized number the calibration layer fits)."""
        return self.episodes * 1e3 / max(self.slices, 1)

    @property
    def latency_slices(self) -> float:
        return self._lat_sum / max(self.episodes, 1)

    _lat_sum: int = 0
    aborts: int = 0


def resolve_ir(lock, n_threads: int, *, ncs_max: int = 0,
               cs_shared=True) -> LockIR:
    """Accept a registered lock name, a spec author function, or an
    already-lowered ``LockIR``."""
    if isinstance(lock, LockIR):
        return lock
    if isinstance(lock, str):
        from repro.core.locks.specs import SPECS
        return lower_spec(SPECS[lock], n_threads, ncs_max=ncs_max,
                          cs_shared=cs_shared, name=lock)
    return lower_spec(lock, n_threads, ncs_max=ncs_max, cs_shared=cs_shared)


# --- kernel -------------------------------------------------------------------

def _build_kernel(ir: LockIR, n_threads: int, atomics: PallasAtomics):
    """The per-slice kernel body. All state flows through the aliased
    output refs; the input refs only seed them."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    T = n_threads
    R = ir.n_regs
    handlers = ir.handlers
    i32 = jnp.int32

    def kernel(*refs):
        # inputs [0:13] alias outputs [13:26]; operate on the outputs
        (mem, pc, regs, cur_op, rng, tmo, episodes, returns,
         arrive_slice, lat_sum, held, scalars, adm_log) = refs[13:]
        r_idx = pl.program_id(0)
        t = pl.program_id(1).astype(i32)
        slice_idx = r_idx.astype(i32) * T + t

        kind, addr = cur_op[t, i32(0)], cur_op[t, i32(1)]
        a, b = cur_op[t, i32(2)], cur_op[t, i32(3)]

        # -- op classes (ir.OP_TABLE as traced masks) -----------------------
        is_park_to = ((kind == M.PARK_EQ_TIMEOUT)
                      | (kind == M.PARK_NE_TIMEOUT))
        eq_wait = ((kind == M.SPIN_EQ) | (kind == M.PARK_EQ)
                   | (kind == M.PARK_EQ_TIMEOUT))
        ne_wait = (kind == M.SPIN_NE) | (kind == M.PARK_NE_TIMEOUT)

        # -- wait check + timed-park probe budget ---------------------------
        watched = atomics.load(mem, addr)
        unsat = (eq_wait & (watched != a)) | (ne_wait & (watched == a))
        budget = tmo[t]
        armed = budget >= 0
        timed_out = is_park_to & unsat & armed & (budget <= 0)
        spin_unsat = unsat & ~timed_out
        do_exec = ~spin_unsat
        # timeouts are probe-denominated on this backend: the op's
        # timeout operand counts unsatisfied rounds, not sim cycles
        tmo[t] = jnp.where(do_exec, i32(-1),
                           jnp.where(is_park_to,
                                     jnp.where(armed, budget - 1, b),
                                     budget))

        # -- memory effect: one atomic RMW per the contract table ----------
        # (waits/loads/delays write the old value back — a no-op by value;
        # device mode serializes the window through the atomics guard)
        eff_kind = jnp.where(do_exec, kind, i32(M.NOP))
        old = atomics.rmw(mem, addr, eff_kind, a, b)

        # -- result encoding ------------------------------------------------
        cas_ok = (kind == M.CAS) & (old == a)
        res = jnp.where(kind == M.CAS, old * 2 + cas_ok.astype(i32),
                        jnp.where(is_park_to,
                                  old * 2 + jnp.where(timed_out, 0, 1),
                                  old))

        # -- DELAY burns real slices-worth of work --------------------------
        iters = jnp.where(do_exec & (kind == M.DELAY), a, 0)
        burn = jax.lax.fori_loop(0, iters, lambda i, x: x + i, 0)
        scalars[i32(3)] = scalars[i32(3)] + burn

        # -- transition: dispatch the IR handler at pc ----------------------
        pc_t = pc[t]
        regs_t = jnp.stack([regs[t, i32(i)] for i in range(R)])
        outs = jax.lax.switch(pc_t, [partial(h, t) for h in handlers],
                              regs_t, res, rng[t])
        regs_new, next_pc, next_op, arrive, admit, rng_new = outs

        pc[t] = jnp.where(do_exec, next_pc, pc_t)
        rng[t] = jnp.where(do_exec, rng_new, rng[t])
        for i in range(R):
            regs[t, i32(i)] = jnp.where(do_exec, regs_new[i],
                                        regs[t, i32(i)])
        for i in range(4):
            op_i = jnp.asarray(next_op[i], i32)
            cur_op[t, i32(i)] = jnp.where(do_exec, op_i, cur_op[t, i32(i)])

        # -- metrics --------------------------------------------------------
        arrive_eff = do_exec & arrive
        admit_eff = do_exec & admit
        ret = do_exec & (next_pc == 0) & (pc_t != 0)

        arrive_slice[t] = jnp.where(arrive_eff, slice_idx, arrive_slice[t])
        lat_sum[t] = lat_sum[t] + jnp.where(
            admit_eff, slice_idx - arrive_slice[t], 0)
        episodes[t] = episodes[t] + admit_eff.astype(i32)
        returns[t] = returns[t] + ret.astype(i32)

        # admission ring with a spill slot at ADM_LOG_M: non-admissions
        # and overflow both land in the spill, real entries in 0..K-1
        cnt = scalars[i32(0)]
        pos = jnp.where(admit_eff, jnp.minimum(cnt, ADM_LOG_M),
                        i32(ADM_LOG_M))
        adm_log[pos] = jnp.where(admit_eff, t, adm_log[pos])
        scalars[i32(0)] = cnt + admit_eff.astype(i32)

        # mutual-exclusion guard: admitted while someone else holds the
        # admit..NCS-return window => collision (must never happen)
        g = scalars[i32(1)]
        scalars[i32(2)] = scalars[i32(2)] + jnp.where(
            admit_eff & (g != 0), 1, 0)
        dec = (ret & (held[t] != 0)).astype(i32)
        scalars[i32(1)] = g + admit_eff.astype(i32) - dec
        held[t] = jnp.where(admit_eff, i32(1),
                            jnp.where(ret, i32(0), held[t]))

    return kernel


def _initial_buffers(ir: LockIR, n_threads: int, seed: int):
    import jax.numpy as jnp
    T, R = n_threads, ir.n_regs
    mem0 = jnp.zeros(max(ir.n_mem, GUARD_WORD + 1), jnp.int32)
    for a, v in ir.init_mem:
        mem0 = mem0.at[a].set(v)
    rng0 = (jnp.arange(T, dtype=jnp.uint32) * jnp.uint32(2654435761)
            + jnp.uint32(seed) * jnp.uint32(97) + jnp.uint32(1))
    nop = jnp.broadcast_to(jnp.array([M.NOP, 0, 0, 0], jnp.int32), (T, 4))
    return (
        mem0,                                         # mem
        jnp.zeros(T, jnp.int32),                      # pc
        jnp.zeros((T, R), jnp.int32),                 # regs
        nop,                                          # cur_op
        rng0,                                         # rng
        jnp.full(T, -1, jnp.int32),                   # tmo
        jnp.zeros(T, jnp.int32),                      # episodes
        jnp.zeros(T, jnp.int32),                      # returns
        jnp.zeros(T, jnp.int32),                      # arrive_slice
        jnp.zeros(T, jnp.int32),                      # lat_sum
        jnp.zeros(T, jnp.int32),                      # held
        jnp.zeros(4, jnp.int32),     # scalars: adm_cnt, guard, coll, burn
        jnp.full(ADM_LOG_M + 1, -1, jnp.int32),       # adm_log (+spill)
    )


def run_measured(lock, n_threads: int, rounds: int, *, ncs_max: int = 0,
                 cs_shared=True, seed: int = 0,
                 interpret: bool | None = None) -> MeasuredResult:
    """Run ``lock`` on the Pallas backend for ``rounds`` round-robin
    rounds of one micro-op per thread. ``interpret=None`` auto-selects:
    interpret mode on CPU (the everywhere-runnable fallback), compiled
    device kernels when an accelerator is present."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    ir = resolve_ir(lock, n_threads, ncs_max=ncs_max, cs_shared=cs_shared)
    atomics = PallasAtomics(interpret=interpret, guard_idx=GUARD_WORD)
    kernel = _build_kernel(ir, n_threads, atomics)
    inits = _initial_buffers(ir, n_threads, seed)
    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype) for x in inits]

    call = pl.pallas_call(
        kernel,
        grid=(rounds, n_threads),
        out_shape=out_shape,
        input_output_aliases={i: i for i in range(len(inits))},
        interpret=interpret,
    )
    fn = jax.jit(call)
    t0 = time.time()
    jax.block_until_ready(fn(*inits))          # trace + compile + warm
    compile_s = time.time() - t0
    t0 = time.time()
    outs = jax.block_until_ready(fn(*inits))   # the timed launch
    wall = time.time() - t0

    (_mem, _pc, _regs, _op, _rng, _tmo, episodes, returns, _arr, lat_sum,
     _held, scalars, adm_log) = (np.asarray(o) for o in outs)
    eps = int(episodes.sum())
    rets = int(returns.sum())
    r = MeasuredResult(
        name=ir.name, n_threads=n_threads, rounds=rounds,
        backend="pallas-interpret" if interpret else "pallas-device",
        episodes=eps, per_thread=episodes, collisions=int(scalars[2]),
        admissions=adm_log[:ADM_LOG_M],
        admission_counts=int(scalars[0]), returns=rets,
        wall_s=wall, compile_s=compile_s)
    r._lat_sum = int(lat_sum.sum())
    r.aborts = max(rets - eps, 0)
    return r


# --- backend catalogue --------------------------------------------------------

def _probe_pallas(interpret: bool) -> tuple[bool, str]:
    """Can this process run a minimal aliased-state Pallas kernel (with
    the atomics layer) in the given mode?"""
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        atomics = PallasAtomics(interpret=interpret, guard_idx=0)

        def k(x_ref, o_ref):
            old = atomics.fetch_add(o_ref, jnp.int32(1), jnp.int32(2))
            o_ref[jnp.int32(0)] = old

        out = pl.pallas_call(
            k, grid=(2,),
            out_shape=jax.ShapeDtypeStruct((2,), jnp.int32),
            input_output_aliases={0: 0},
            interpret=interpret,
        )(jnp.array([5, 7], jnp.int32))
        ok = int(np.asarray(out)[1]) == 11
        return ok, "ok" if ok else f"probe mismatch: {np.asarray(out)}"
    except Exception as e:                      # noqa: BLE001
        return False, f"{type(e).__name__}: {e}"[:120]


def backends() -> list:
    """The backend catalogue with availability probing — what
    ``repro.bench list --backends`` prints. Rows:
    ``{"name", "available", "detail"}``."""
    import jax
    rows = [{
        "name": "sim",
        "available": True,
        "detail": "discrete-time coherence interpreter "
                  "(core/sim/machine.py handler tables under lax.scan)",
    }]
    ok, detail = _probe_pallas(interpret=True)
    rows.append({
        "name": "pallas-interpret",
        "available": ok,
        "detail": ("Pallas kernel, interpreter mode (CPU fallback; "
                   "sequential grid => emulated RMWs are linearizable)"
                   if ok else detail),
    })
    plat = jax.default_backend()
    if plat == "cpu":
        rows.append({
            "name": "pallas-device",
            "available": False,
            "detail": f"no accelerator (jax backend: {plat})",
        })
    else:
        ok, detail = _probe_pallas(interpret=False)
        rows.append({
            "name": "pallas-device",
            "available": ok,
            "detail": (f"compiled Pallas kernel on {plat} "
                       "(pl.atomic_* + guard-lock splices)"
                       if ok else detail),
        })
    return rows
