"""Portable lock IR: the backend-neutral middle of the compile pipeline.

``LockSpec`` phase specs lower in two stages::

    LockSpec --lower_spec--> LockIR --+--> to_sim_program -> sim Program
                                      +--> pallas_backend  -> device kernel

``lower_spec`` does everything that is *backend-neutral* about
compilation — phase flattening, label -> program-counter resolution,
register binding, memory-region layout/NUMA homing, the injected
NCS/CS-profile scaffolding, the eager per-handler abstract trace, and
the structural ``cfg.py`` verification gate. The result, a
:class:`LockIR`, carries the resolved handler table in the machine's
calling convention plus the layout/phase metadata a backend needs to
schedule it.

Backends:

* **sim** (:func:`to_sim_program`) — wraps the IR into the
  ``core/sim/machine.py`` ``Program`` handler-table form, executed by
  the discrete-time coherence interpreter under ``lax.scan``. The IR
  carries the *same handler closures* the historical one-shot compiler
  built, so lowering through the IR is bit-identical to the pre-IR
  pipeline (pinned by ``tests/test_ir_backends.py`` golden digests for
  every spec in the zoo) and leaves experiment-cache fingerprints
  (``bench/cache.py`` jaxpr hashes) unchanged.
* **pallas** (``core/locks/pallas_backend.py``) — lowers the same IR to
  a ``pl.pallas_call`` kernel where each thread is a grid program
  hammering the lock words through the device atomics layer
  (``core/runtime/atomics.py``); the *measured* tier of the sim->silicon
  loop.

Op semantics and result encodings are defined once, in the contract
table at the top of ``core/sim/machine.py``; :data:`OP_TABLE` exposes
that contract as data (per-op class and result encoding) so backends
and tools can branch on op *kind* without re-deriving the taxonomy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.locks.dsl import (
    CS2_WORD, CS_WORD, Ctx, LockSpec, SpecError, _b, _i,
)
from repro.core.sim import machine as M
from repro.core.sim.machine import DELAY, LOAD, Program, STORE

__all__ = ["LockIR", "OP_TABLE", "OpInfo", "lower_spec", "to_sim_program",
           "build_spec", "describe_spec"]


# --- the op/result-encoding contract, as data --------------------------------

@dataclass(frozen=True)
class OpInfo:
    """One row of the machine's op contract table (``core/sim/machine.py``):
    what the op reads/writes and how its result packs."""
    name: str
    kind: int
    is_load: bool            # reads the addressed word
    is_store: bool           # writes (takes the line exclusive)
    is_wait: bool            # may block until the word (dis)satisfies
    result: str              # result encoding fed to the next handler


#: kind -> OpInfo for every machine op. ``old2ok`` packs ``old * 2 + ok``
#: (CAS and the timed parks); waits deliver the watched value once
#: satisfied; pure delays deliver the unchanged previous result.
OP_TABLE = {
    o.kind: o for o in (
        OpInfo("NOP", M.NOP, False, False, False, "unchanged"),
        OpInfo("LOAD", M.LOAD, True, False, False, "value"),
        OpInfo("STORE", M.STORE, False, True, False, "value"),
        OpInfo("XCHG", M.XCHG, True, True, False, "old"),
        OpInfo("CAS", M.CAS, True, True, False, "old2ok"),
        OpInfo("FAA", M.FAA, True, True, False, "old"),
        OpInfo("SPIN_EQ", M.SPIN_EQ, True, False, True, "value"),
        OpInfo("SPIN_NE", M.SPIN_NE, True, False, True, "value"),
        OpInfo("DELAY", M.DELAY, False, False, False, "unchanged"),
        OpInfo("PARK_EQ", M.PARK_EQ, True, False, True, "value"),
        OpInfo("PARK_EQ_TIMEOUT", M.PARK_EQ_TIMEOUT, True, False, True,
               "old2ok"),
        OpInfo("PARK_NE_TIMEOUT", M.PARK_NE_TIMEOUT, True, False, True,
               "old2ok"),
    )
}


# --- the IR -------------------------------------------------------------------

@dataclass(frozen=True)
class LockIR:
    """A lowered lock, backend-neutral.

    ``handlers[pc](t, regs, res, rng) -> (regs, next_pc, op4, arrive,
    admit, rng)`` is the machine calling convention — already resolved
    (labels -> pcs, registers -> indices, scaffolding injected), pure
    jnp, traceable under ``lax.switch`` by any backend. The remaining
    fields are layout and phase metadata:

    * ``n_mem`` / ``home`` / ``init_mem`` — word count, per-word NUMA
      home thread (-1 => node 0), initial values.
    * ``labels`` — label -> pc for every declared step (plus ``ncs``).
    * ``phases`` — pc -> phase tag, including the injected ``"ncs"``
      (pc 0) and ``"cs"`` (the second-CS handler) scaffolding.
    * ``release_pc`` / ``cs2_pc`` — where the release phase starts and
      where ``enter_cs`` routes, for backends that instrument the
      critical-section window.
    * ``cs_mode`` / ``ncs_max`` — the workload profile baked into the
      scaffolding handlers (``rw`` / ``ro`` / ``local``, NCS delay cap).
    """
    name: str
    handlers: tuple
    n_mem: int
    home: tuple
    init_mem: tuple
    n_threads: int
    labels: tuple            # ((label, pc), ...) in pc order
    phases: tuple            # pc -> phase string
    release_pc: int
    cs2_pc: int
    cs_mode: str
    ncs_max: int
    n_regs: int = Program.n_regs

    @property
    def n_handlers(self) -> int:
        return len(self.handlers)

    def label_of(self, pc: int) -> str:
        for lab, p in self.labels:
            if p == pc:
                return lab
        return f"pc{pc}"


def _xorshift(r):
    r = r ^ (r << jnp.uint32(13))
    r = r ^ (r >> jnp.uint32(17))
    r = r ^ (r << jnp.uint32(5))
    return r


def _cs_mode(cs_shared) -> str:
    return cs_shared if isinstance(cs_shared, str) else (
        "rw" if cs_shared else "local")


def _cs1_op(cs_shared) -> tuple:
    # plain ints, not jnp scalars: the emitting handler wraps them at
    # trace time, so backends (Pallas kernels in particular) never close
    # over pre-created arrays
    mode = _cs_mode(cs_shared)
    if mode in ("rw", "ro"):
        return (LOAD, CS_WORD, 0, 0)
    return (DELAY, 0, 1, 0)


def _cs2_op(cs_shared, res) -> tuple:
    mode = _cs_mode(cs_shared)
    if mode == "rw":
        return (_i(STORE), _i(CS_WORD), _i(res + 1), _i(0))
    if mode == "ro":
        return (_i(LOAD), _i(CS2_WORD), _i(0), _i(0))
    return (_i(DELAY), _i(0), _i(1), _i(0))


def _ncs_handler(next_pc: int, ncs_max: int):
    def h(t, regs, res, rng):
        rng = _xorshift(rng)
        d = _i(rng % jnp.uint32(max(ncs_max, 1))) * (ncs_max > 0)
        return (regs, _i(next_pc), (_i(DELAY), _i(0), d, _i(0)),
                _b(False), _b(False), rng)
    return h


def build_spec(author: Callable, n_threads: int,
               name: str | None = None) -> LockSpec:
    """Run the author function; return the populated, validated builder."""
    spec = LockSpec(name or author.__name__, n_threads)
    author(spec)
    spec.validate()
    return spec


def describe_spec(author: Callable, n_threads: int = 2) -> dict:
    """Introspect a spec without lowering it: phase -> step labels, plus
    the memory layout (for ``python -m repro.bench list --programs``)."""
    spec = build_spec(author, n_threads)
    return {
        "name": spec.name,
        "phases": spec.phase_summary(),
        "n_steps": len(spec.steps),
        "regs": sorted(spec.regmap, key=spec.regmap.get),
        "words": dict(spec.words),
        "regions": [(r.name, r.size, "per-thread" if r.homed else "global")
                    for r in spec.regions],
    }


def lower_spec(author: Callable, n_threads: int, *, ncs_max: int = 0,
               cs_shared=True, name: str | None = None) -> LockIR:
    """Lower ``author``'s spec to the backend-neutral :class:`LockIR`.

    This is the whole backend-independent compile: phase flattening,
    label/register resolution, scaffolding injection, the eager
    per-handler abstract trace (unknown labels/registers and untraceable
    steps are *compile-time* ``SpecError``s), and the structural
    ``cfg.py`` verification gate.
    """
    spec = build_spec(author, n_threads, name)
    T = n_threads

    # pc layout: 0 = injected NCS; 1..N = declared steps; N+1 = injected
    # second-CS handler. NCS label -> 0 closes the episode loop.
    labels = {"ncs": 0}
    for i, st in enumerate(spec.steps):
        labels[st.label] = 1 + i
    cs2_pc = 1 + len(spec.steps)
    release_pc = next(labels[st.label] for st in spec.steps
                      if st.phase == "release")
    cs1 = _cs1_op(cs_shared)

    def make_handler(idx: int):
        st = spec.steps[idx]
        fallthrough = 2 + idx if idx + 1 < len(spec.steps) else None

        def h(t, regs, res, rng):
            c = Ctx(t=t, T=T, res=res, regs=regs, rng=rng,
                    regmap=spec.regmap, labels=labels,
                    fallthrough=fallthrough, cs1_op=cs1, cs2_pc=cs2_pc)
            try:
                out = st.fn(c)
            except SpecError as e:
                raise SpecError(f"{spec.name}.{st.label}: {e}") from e
            if out is None:
                raise SpecError(f"{spec.name}.{st.label}: step returned "
                                "None (must return c.op/c.when/c.enter_cs)")
            op = tuple(_i(x) for x in out.op)
            return (c.r._arr, _i(out.pc), op,
                    _b(out.arrive), _b(out.admit), rng)
        return h

    def cs2_handler(t, regs, res, rng):
        return (regs, _i(release_pc), _cs2_op(cs_shared, res),
                _b(False), _b(False), rng)

    handlers = tuple([_ncs_handler(1, ncs_max)]
                     + [make_handler(i) for i in range(len(spec.steps))]
                     + [cs2_handler])
    # Eager abstract trace of every handler: unknown labels/registers,
    # steps returning None, and bad fallthroughs are *compile-time*
    # errors, not mid-sweep tracer failures.
    probe = (jnp.int32(0), jnp.zeros((Program.n_regs,), jnp.int32),
             jnp.int32(0), jnp.uint32(1))
    for st, h in zip(spec.steps, handlers[1:]):
        try:
            jax.eval_shape(h, *probe)
        except SpecError:
            raise
        except Exception as e:
            raise SpecError(
                f"{spec.name}.{st.label}: step failed to trace: {e}") from e
    # Cheap structural verification (core/locks/cfg.py): loop-free
    # doorway/release by default, plus two-sided checks of any
    # s.expect(...) declarations. Violations are SpecErrors with
    # phase/label provenance; a spec body the recorder cannot replay
    # (exotic jnp use) degrades to unverified rather than failing the
    # compile — the `repro.bench verify` CLI reports it as such.
    from repro.core.locks import cfg as _cfg
    try:
        facts = _cfg.analyze(spec)
    except SpecError:
        raise
    except Exception:
        facts = None
    if facts is not None:
        violations = _cfg.check_spec(facts)
        if violations:
            raise SpecError(f"{spec.name}: {violations[0]}")
    phases = tuple(["ncs"] + [st.phase for st in spec.steps] + ["cs"])
    return LockIR(
        name=spec.name, handlers=handlers, n_mem=spec.n_mem,
        home=spec.home(), init_mem=tuple(spec.inits), n_threads=T,
        labels=tuple(sorted(labels.items(), key=lambda kv: kv[1])),
        phases=phases, release_pc=release_pc, cs2_pc=cs2_pc,
        cs_mode=_cs_mode(cs_shared), ncs_max=ncs_max)


def to_sim_program(ir: LockIR) -> Program:
    """Backend #1: wrap the IR for the discrete-time sim machine. The
    handler tuple is passed through untouched — sim lowering through the
    IR is bit-identical to the historical one-shot compiler."""
    return Program(handlers=ir.handlers, n_mem=ir.n_mem, home=ir.home,
                   name=ir.name, init_mem=ir.init_mem)
