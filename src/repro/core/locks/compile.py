"""Compiler: lower a declarative ``LockSpec`` to a ``Program`` handler table.

``compile_spec(author, n_threads, ncs_max=..., cs_shared=...)`` runs the
spec author function against a fresh :class:`~repro.core.locks.dsl.LockSpec`
builder, assigns program counters to the labelled steps, and injects the
scaffolding every lock shares instead of having each lock restate it:

* **pc 0 — NCS handler.** The MutexBench non-critical section (paper
  §7.1): a per-thread xorshift-driven ``DELAY`` of up to ``ncs_max``
  cycles, then jump to the first declared step.
* **CS profile handlers.** ``c.enter_cs()`` emits the first CS op and
  routes through an injected second-CS handler into the first ``release``
  step. Profiles (selected by ``cs_shared``): ``"rw"``/``True`` — advance
  the shared PRNG word (Figs 1-2), ``"ro"`` — two read-only lookups
  (LevelDB-readrandom analogue, Fig. 3), ``"local"``/``False`` — a
  degenerate local CS (Table 1).

The lowered ``Program`` is exactly the handler-table form
``core/sim/machine.py`` consumes — handler at ``pc`` gets
``(t, regs, res, rng)`` and returns ``(regs, next_pc, op4, arrive, admit,
rng)``, with op/result encodings per the machine.py contract table — so
compiled specs drop into ``run_machine`` / the ``SimEngine`` session API
and the ``repro.bench`` sweep driver unchanged.

NUMA homing lowers *thread-indexed*: a ``s.per_thread(...)`` region
becomes ``Program.home[base + i] = i`` (thread i's sequestered line) and
lock/global words get ``-1`` (homed with thread 0, "node 0"). Which
physical domain that means is the machine's business — the engine's
cost-matrix lookup ``LoweredCost.miss[t, home]`` composes the home table
with the topology's thread→leaf *placement* (``core/sim/topology.py``),
so one compiled program runs unchanged on every machine, including
interleaved pinnings.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.locks.dsl import (
    CS2_WORD, CS_WORD, Ctx, LockSpec, SpecError, _b, _i,
)
from repro.core.sim.machine import DELAY, LOAD, Program, STORE

__all__ = ["compile_spec", "describe_spec", "build_spec"]


def _xorshift(r):
    r = r ^ (r << jnp.uint32(13))
    r = r ^ (r >> jnp.uint32(17))
    r = r ^ (r << jnp.uint32(5))
    return r


def _cs_mode(cs_shared) -> str:
    return cs_shared if isinstance(cs_shared, str) else (
        "rw" if cs_shared else "local")


def _cs1_op(cs_shared) -> tuple:
    mode = _cs_mode(cs_shared)
    if mode in ("rw", "ro"):
        return (_i(LOAD), _i(CS_WORD), _i(0), _i(0))
    return (_i(DELAY), _i(0), _i(1), _i(0))


def _cs2_op(cs_shared, res) -> tuple:
    mode = _cs_mode(cs_shared)
    if mode == "rw":
        return (_i(STORE), _i(CS_WORD), _i(res + 1), _i(0))
    if mode == "ro":
        return (_i(LOAD), _i(CS2_WORD), _i(0), _i(0))
    return (_i(DELAY), _i(0), _i(1), _i(0))


def _ncs_handler(next_pc: int, ncs_max: int):
    def h(t, regs, res, rng):
        rng = _xorshift(rng)
        d = _i(rng % jnp.uint32(max(ncs_max, 1))) * (ncs_max > 0)
        return (regs, _i(next_pc), (_i(DELAY), _i(0), d, _i(0)),
                _b(False), _b(False), rng)
    return h


def build_spec(author: Callable, n_threads: int,
               name: str | None = None) -> LockSpec:
    """Run the author function; return the populated, validated builder."""
    spec = LockSpec(name or author.__name__, n_threads)
    author(spec)
    spec.validate()
    return spec


def describe_spec(author: Callable, n_threads: int = 2) -> dict:
    """Introspect a spec without lowering it: phase -> step labels, plus
    the memory layout (for ``python -m repro.bench list --programs``)."""
    spec = build_spec(author, n_threads)
    return {
        "name": spec.name,
        "phases": spec.phase_summary(),
        "n_steps": len(spec.steps),
        "regs": sorted(spec.regmap, key=spec.regmap.get),
        "words": dict(spec.words),
        "regions": [(r.name, r.size, "per-thread" if r.homed else "global")
                    for r in spec.regions],
    }


def compile_spec(author: Callable, n_threads: int, *, ncs_max: int = 0,
                 cs_shared=True, name: str | None = None) -> Program:
    """Lower ``author``'s spec to a ``core.sim.machine.Program``.

    Keeps the signature of the historical per-lock builder functions, so a
    ``functools.partial(compile_spec, author)`` is a drop-in entry for the
    ``PROGRAMS`` registry.
    """
    spec = build_spec(author, n_threads, name)
    T = n_threads

    # pc layout: 0 = injected NCS; 1..N = declared steps; N+1 = injected
    # second-CS handler. NCS label -> 0 closes the episode loop.
    labels = {"ncs": 0}
    for i, st in enumerate(spec.steps):
        labels[st.label] = 1 + i
    cs2_pc = 1 + len(spec.steps)
    release_pc = next(labels[st.label] for st in spec.steps
                      if st.phase == "release")
    cs1 = _cs1_op(cs_shared)

    def make_handler(idx: int):
        st = spec.steps[idx]
        fallthrough = 2 + idx if idx + 1 < len(spec.steps) else None

        def h(t, regs, res, rng):
            c = Ctx(t=t, T=T, res=res, regs=regs, rng=rng,
                    regmap=spec.regmap, labels=labels,
                    fallthrough=fallthrough, cs1_op=cs1, cs2_pc=cs2_pc)
            try:
                out = st.fn(c)
            except SpecError as e:
                raise SpecError(f"{spec.name}.{st.label}: {e}") from e
            if out is None:
                raise SpecError(f"{spec.name}.{st.label}: step returned "
                                "None (must return c.op/c.when/c.enter_cs)")
            op = tuple(_i(x) for x in out.op)
            return (c.r._arr, _i(out.pc), op,
                    _b(out.arrive), _b(out.admit), rng)
        return h

    def cs2_handler(t, regs, res, rng):
        return (regs, _i(release_pc), _cs2_op(cs_shared, res),
                _b(False), _b(False), rng)

    handlers = tuple([_ncs_handler(1, ncs_max)]
                     + [make_handler(i) for i in range(len(spec.steps))]
                     + [cs2_handler])
    # Eager abstract trace of every handler: unknown labels/registers,
    # steps returning None, and bad fallthroughs are *compile-time*
    # errors, not mid-sweep tracer failures.
    probe = (jnp.int32(0), jnp.zeros((Program.n_regs,), jnp.int32),
             jnp.int32(0), jnp.uint32(1))
    for st, h in zip(spec.steps, handlers[1:]):
        try:
            jax.eval_shape(h, *probe)
        except SpecError:
            raise
        except Exception as e:
            raise SpecError(
                f"{spec.name}.{st.label}: step failed to trace: {e}") from e
    # Cheap structural verification (core/locks/cfg.py): loop-free
    # doorway/release by default, plus two-sided checks of any
    # s.expect(...) declarations. Violations are SpecErrors with
    # phase/label provenance; a spec body the recorder cannot replay
    # (exotic jnp use) degrades to unverified rather than failing the
    # compile — the `repro.bench verify` CLI reports it as such.
    from repro.core.locks import cfg as _cfg
    try:
        facts = _cfg.analyze(spec)
    except SpecError:
        raise
    except Exception:
        facts = None
    if facts is not None:
        violations = _cfg.check_spec(facts)
        if violations:
            raise SpecError(f"{spec.name}: {violations[0]}")
    return Program(handlers=handlers, n_mem=spec.n_mem, home=spec.home(),
                   name=spec.name, init_mem=tuple(spec.inits))
