"""Compiler façade: lower a declarative ``LockSpec`` to a backend.

The actual lowering lives in ``core/locks/ir.py`` — ``lower_spec``
produces the backend-neutral :class:`~repro.core.locks.ir.LockIR`
(phase flattening, label/register resolution, region layout/NUMA
homing, injected NCS/CS scaffolding, the eager abstract trace and the
structural ``cfg.py`` gate), and each backend consumes the IR:

* ``compile_spec`` here — the sim backend: ``LockIR`` wrapped into the
  ``core/sim/machine.py`` ``Program`` handler-table form. Keeps the
  historical per-lock builder signature so a
  ``functools.partial(compile_spec, author)`` is a drop-in entry for
  the ``PROGRAMS`` registry, and is bit-identical to the pre-IR
  one-shot compiler (``tests/test_ir_backends.py`` pins the digests).
* ``core/locks/pallas_backend.py`` — the measured backend: the same IR
  lowered to a ``pl.pallas_call`` kernel over real device atomics.

Scaffolding semantics (paper §7.1 NCS delay, the ``rw``/``ro``/``local``
CS profiles) and the NUMA-homing convention (``s.per_thread`` regions
homed on the owning thread, lock/global words on node 0) are documented
on ``lower_spec``; op/result encodings are the contract table at the
top of ``core/sim/machine.py``, exposed as data in ``ir.OP_TABLE``.
"""
from __future__ import annotations

from typing import Callable

from repro.core.locks.ir import (           # noqa: F401  (re-exports)
    build_spec, describe_spec, lower_spec, to_sim_program,
)
from repro.core.sim.machine import Program

__all__ = ["compile_spec", "describe_spec", "build_spec"]


def compile_spec(author: Callable, n_threads: int, *, ncs_max: int = 0,
                 cs_shared=True, name: str | None = None) -> Program:
    """Lower ``author``'s spec to a ``core.sim.machine.Program`` —
    ``lower_spec`` (backend-neutral IR) then ``to_sim_program``
    (Backend #1)."""
    return to_sim_program(lower_spec(author, n_threads, ncs_max=ncs_max,
                                     cs_shared=cs_shared, name=name))
