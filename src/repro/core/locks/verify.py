"""Small-scope exhaustive verification of compiled lock specs.

``core/locks/cfg.py`` proves the *shape* claims (constant-time doorway
and release, spin locality, waiting footprint) from the control-flow
graph alone. This module proves the *interleaving* claims the CFG
cannot: for a small thread count and a bounded number of lock episodes
per thread, :func:`model_check` enumerates **all** interleavings of the
compiled handler table — not random schedules like the PR-5 hypothesis
harness — and certifies

* **mutual exclusion** — never two threads with a pending access to the
  shared CS word (the injected ``enter_cs`` scaffolding, word 4);
* **deadlock freedom** — no reachable state where every unfinished
  thread is blocked;
* **no lost wakeups** — no reachable state from which a blocked thread
  can never run again while others still can (a *trap*: under the
  untimed semantics a waiter whose wakeup was dropped stays blocked in
  every future, which the post-hoc reverse reachability pass detects
  even before the other threads drain their episodes into a deadlock);
* **bounded bypass** — per waiting thread, between its ``arrive`` and
  its ``admit``, no other thread is admitted more than ``bypass`` times
  (the paper's reciprocating-family bound is 2; counters saturate, so
  declaring ``bypass=None`` keeps the state space finite for barging
  locks).

The model is *untimed*: one atomic transition executes a thread's
pending memory op and runs the handler at its next pc (handlers are
pure local computation, so this is the natural atomicity grain of
``core/sim/machine.py``). Blocking ops gate enabledness instead of
costing cycles; a timed park whose condition is false takes its timeout
transition (every finite patience is eventually exceeded under some
schedule, so the untimed model must always offer it). Handler calls are
memoized on ``(t, pc, regs, res)`` — the PRNG only feeds the NCS delay,
which is zero here.

On violation the BFS parent chain yields a *minimal* counterexample
trace (fewest transitions from the initial state), with step labels and
symbolic operand names for provenance.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp

from repro.core.locks import cfg as cfg_mod
from repro.core.locks.compile import build_spec, compile_spec
from repro.core.locks.dsl import CS_WORD, LockSpec, SpecError
from repro.core.sim import machine as M

__all__ = ["CheckResult", "LockVerdict", "model_check", "verify_lock",
           "verify_all", "matrix_columns", "matrix_rows", "render_matrix"]


# ---------------------------------------------------------------------------
# The untimed machine: op execution + enabledness
# ---------------------------------------------------------------------------
def _op_enabled(op: tuple, mem: tuple) -> bool:
    kind, addr, a, _ = op
    mval = mem[addr]
    if kind in (M.SPIN_EQ, M.PARK_EQ):
        return mval == a
    if kind == M.SPIN_NE:
        return mval != a
    return True                     # timed parks always fire (timeout)


def _op_exec(op: tuple, mem: tuple):
    """Execute an (enabled) op: -> (res, write-or-None)."""
    kind, addr, a, b = op
    mval = mem[addr]
    if kind in (M.STORE, M.XCHG):
        return mval, (addr, a)
    if kind == M.CAS:
        ok = 1 if mval == a else 0
        return mval * 2 + ok, ((addr, b) if ok else None)
    if kind == M.FAA:
        return mval, (addr, mval + a)
    if kind == M.PARK_EQ_TIMEOUT:
        return mval * 2 + (1 if mval == a else 0), None
    if kind == M.PARK_NE_TIMEOUT:
        return mval * 2 + (1 if mval != a else 0), None
    return mval, None               # NOP / DELAY / LOAD / satisfied waits


def _addr_name(spec: LockSpec, addr: int) -> str:
    for n, a in spec.words.items():
        if a == addr:
            return n
    if addr == CS_WORD:
        return "CS"
    for r in spec.regions:
        if r.base <= addr < r.base + r.size:
            return f"{r.name}[{addr - r.base}]"
    return str(addr)


def _op_desc(spec: LockSpec, op: tuple) -> str:
    kind, addr, a, b = op
    name = cfg_mod.KIND_NAMES.get(kind, str(kind))
    at = _addr_name(spec, addr)
    if kind in (M.STORE, M.XCHG, M.FAA):
        return f"{name}({at}, {a})"
    if kind == M.CAS:
        return f"{name}({at}, {a}->{b})"
    if kind in (M.SPIN_EQ, M.SPIN_NE, M.PARK_EQ,
                M.PARK_EQ_TIMEOUT, M.PARK_NE_TIMEOUT):
        return f"{name}({at}, {a})"
    return f"{name}({at})"


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------
@dataclass
class CheckResult:
    """Outcome of one exhaustive small-scope run."""
    name: str
    n_threads: int
    episodes: int
    states: int                 # states expanded
    closed: bool                # state space exhausted within budget
    ok: bool
    violation: str | None = None    # mutual_exclusion | deadlock |
    #                                 lost_wakeup | bypass
    detail: str = ""
    trace: list = field(default_factory=list)   # minimal counterexample
    max_bypass: int = 0         # observed waiting-window bypass (saturated)
    bypass_cap: int = 0         # saturation cap (observed == cap: ">=cap")

    @property
    def certificate(self) -> str:
        if not self.ok:
            return f"✗ {self.violation}"
        scope = f"T={self.n_threads} E={self.episodes}"
        kind = "exhaustive" if self.closed else "bounded"
        return f"✓ {kind} ({scope}, {self.states} states)"


def model_check(author, n_threads: int = 2, *, episodes: int = 2,
                max_states: int = 200_000, name: str | None = None,
                bypass_bound: int | None = None,
                bypass_cap: int | None = None) -> CheckResult:
    """Exhaustively enumerate all interleavings of the compiled spec for
    ``n_threads`` threads x ``episodes`` lock episodes each.

    ``bypass_bound`` (an int) turns the waiting-window bypass counter
    into a checked property; ``None`` only measures it. Counters
    saturate at ``bypass_cap`` (default ``bound + 1``, or 3) so barging
    locks keep a finite state space.
    """
    spec = build_spec(author, n_threads, name)
    prog = compile_spec(author, n_threads, name=name)
    T = n_threads
    cap = bypass_cap if bypass_cap is not None else (
        (bypass_bound + 1) if bypass_bound is not None else 3)

    # pc -> label (provenance for traces): mirrors compile_spec's layout
    labels = {0: "ncs"}
    for i, st in enumerate(spec.steps):
        labels[1 + i] = st.label
    labels[1 + len(spec.steps)] = "@cs"

    mem0 = [0] * prog.n_mem
    for a, v in prog.init_mem:
        mem0[a] = v
    mem0 = tuple(mem0)
    NOPOP = (int(M.NOP), 0, 0, 0)
    zeros = (0,) * prog.n_regs
    zctr = (0,) * T
    # thread tuple: (pc, regs, op-or-None, episodes, waiting, counters)
    th0 = (0, zeros, NOPOP, 0, False, zctr)
    init = (mem0, (th0,) * T)

    memo: dict = {}

    def call(t, pc, regs, res):
        key = (t, pc, regs, res)
        hit = memo.get(key)
        if hit is None:
            r, p, op, arrive, admit, _ = prog.handlers[pc](
                jnp.int32(t), jnp.asarray(regs, jnp.int32),
                jnp.int32(res), jnp.uint32(1))
            hit = (tuple(int(x) for x in r), int(p),
                   tuple(int(x) for x in op), bool(arrive), bool(admit))
            memo[key] = hit
        return hit

    def cs_occupants(threads):
        return [t for t, th in enumerate(threads)
                if th[2] is not None and th[2][0] in (M.LOAD, M.STORE)
                and th[2][1] == CS_WORD]

    ids: dict = {init: 0}
    states = [init]
    parents = [(-1, -1, "")]        # (parent id, thread, transition desc)
    succs: list = [[]]
    enabled_of: list = [None]
    depth = [0]
    frontier = [0]
    expanded = 0
    max_bypass_seen = 0
    violation = None                # (kind, detail, state id)

    def trace_to(sid) -> list:
        out = []
        while sid > 0:
            pid, t, desc = parents[sid]
            out.append(f"T{t}: {desc}")
            sid = pid
        out.reverse()
        return out

    while frontier and violation is None:
        next_frontier = []
        for sid in frontier:
            if violation is not None:
                break
            if expanded >= max_states:
                continue            # leave unexpanded (open frontier)
            expanded += 1
            mem, threads = states[sid]
            en = [t for t in range(T) if threads[t][2] is not None
                  and _op_enabled(threads[t][2], mem)]
            enabled_of[sid] = en
            if not en:
                if any(th[2] is not None for th in threads):
                    stuck = "; ".join(
                        f"T{t} blocked at {_op_desc(spec, th[2])}"
                        for t, th in enumerate(threads) if th[2] is not None)
                    violation = ("deadlock", stuck, sid)
                continue
            for t in en:
                pc, regs, op, eps, waiting, ctr = threads[t]
                res, write = _op_exec(op, mem)
                mem2 = mem
                if write is not None:
                    lm = list(mem)
                    lm[write[0]] = write[1]
                    mem2 = tuple(lm)
                desc = _op_desc(spec, op)
                if pc == 0 and eps >= episodes:
                    th2 = (0, regs, None, eps, False, zctr)
                    arrive = admit = False
                    desc += " -> done"
                else:
                    regs2, pc2, op2, arrive, admit = call(t, pc, regs, res)
                    eps2 = eps + (1 if pc == 0 else 0)
                    th2 = (pc2, regs2, op2, eps2, waiting, ctr)
                    desc += f" -> {labels.get(pc, pc)}"
                nthreads = list(threads)
                nthreads[t] = th2
                # --- bypass windows (waiting-window admission counting) ----
                closed_window = None
                if arrive:
                    pcx, rgx, opx, epx, _, _ = nthreads[t]
                    nthreads[t] = (pcx, rgx, opx, epx, True, zctr)
                if admit:
                    for w in range(T):
                        if w == t:
                            continue
                        pcw, rgw, opw, epw, waw, ctw = nthreads[w]
                        if waw:
                            lc = list(ctw)
                            lc[t] = min(lc[t] + 1, cap)
                            nthreads[w] = (pcw, rgw, opw, epw, True,
                                           tuple(lc))
                    pcx, rgx, opx, epx, wax, ctx = nthreads[t]
                    if wax:
                        closed_window = max(ctx)
                        max_bypass_seen = max(max_bypass_seen,
                                              closed_window)
                        nthreads[t] = (pcx, rgx, opx, epx, False, zctr)
                ns = (mem2, tuple(nthreads))
                nid = ids.get(ns)
                if nid is None:
                    nid = len(states)
                    ids[ns] = nid
                    states.append(ns)
                    parents.append((sid, t, desc))
                    succs.append([])
                    enabled_of.append(None)
                    depth.append(depth[sid] + 1)
                    next_frontier.append(nid)
                succs[sid].append(nid)
                # --- property checks on the new state ----------------------
                occ = cs_occupants(ns[1])
                if len(occ) > 1:
                    parents[nid] = (sid, t, desc)
                    violation = (
                        "mutual_exclusion",
                        f"threads {occ} pending CS access together", nid)
                    break
                if (closed_window is not None and bypass_bound is not None
                        and closed_window > bypass_bound):
                    parents[nid] = (sid, t, desc)
                    violation = (
                        "bypass",
                        f"T{t} admitted after a rival was admitted "
                        f"{closed_window}x in its waiting window "
                        f"(declared bound {bypass_bound})", nid)
                    break
        frontier = next_frontier

    closed = not frontier and expanded < max_states and violation is None

    # --- lost wakeups: trap detection over the explored graph --------------
    if violation is None:
        unexpanded = {i for i, e in enumerate(enabled_of) if e is None}
        preds: dict = {}
        for i, ss in enumerate(succs):
            for j in ss:
                preds.setdefault(j, []).append(i)
        for t in range(T):
            good = set(unexpanded)
            good.update(i for i, e in enumerate(enabled_of)
                        if e is not None and t in e)
            seen = set(good)
            stack = list(good)
            while stack:
                j = stack.pop()
                for i in preds.get(j, ()):
                    if i not in seen:
                        seen.add(i)
                        stack.append(i)
            trapped = [i for i in range(len(states))
                       if i not in seen and i not in unexpanded
                       and states[i][1][t][2] is not None]
            if trapped:
                sid = min(trapped, key=depth.__getitem__)
                op = states[sid][1][t][2]
                violation = (
                    "lost_wakeup",
                    f"T{t} is blocked at {_op_desc(spec, op)} and can "
                    "never run again in any future schedule", sid)
                break

    if violation is not None:
        kind, detail, sid = violation
        return CheckResult(
            name=spec.name, n_threads=T, episodes=episodes,
            states=expanded, closed=False, ok=False, violation=kind,
            detail=detail, trace=trace_to(sid),
            max_bypass=max_bypass_seen, bypass_cap=cap)
    return CheckResult(
        name=spec.name, n_threads=T, episodes=episodes, states=expanded,
        closed=closed, ok=True, max_bypass=max_bypass_seen, bypass_cap=cap)


# ---------------------------------------------------------------------------
# The per-lock verdict: structural facts + declarations + model check
# ---------------------------------------------------------------------------
@dataclass
class LockVerdict:
    name: str
    facts: cfg_mod.StructuralFacts | None
    expectations: dict
    structural_violations: list
    check: CheckResult | None
    error: str | None = None

    @property
    def ok(self) -> bool:
        return (self.error is None and not self.structural_violations
                and (self.check is None or self.check.ok))


def verify_lock(author, name: str | None = None, *,
                n_threads: int = 2, episodes: int = 2,
                max_states: int = 200_000, model: bool = True,
                exhaustive: bool = False) -> LockVerdict:
    """Run the full pipeline on one spec: CFG analyses, two-sided
    declaration checks, and the small-scope model check (at 2 threads;
    ``exhaustive`` re-runs at 3 threads; ``model=False`` keeps only the
    cheap structural passes — used by ``list --properties``)."""
    name = name or getattr(author, "__name__", "spec")
    try:
        spec = build_spec(author, 4, name)
        facts = cfg_mod.analyze(spec)
        violations = cfg_mod.check_spec(facts)
        exp = dict(spec.expectations)
    except SpecError as e:
        return LockVerdict(name=name, facts=None, expectations={},
                           structural_violations=[], check=None,
                           error=str(e))
    if not model:
        return LockVerdict(name=name, facts=facts, expectations=exp,
                           structural_violations=violations, check=None)
    bound = exp.get("bypass")
    check = None
    try:
        check = model_check(author, n_threads, episodes=episodes,
                            max_states=max_states, name=name,
                            bypass_bound=bound)
        if check.ok and exhaustive:
            check = model_check(author, 3, episodes=episodes,
                                max_states=max_states, name=name,
                                bypass_bound=bound)
    except SpecError as e:
        return LockVerdict(name=name, facts=facts, expectations=exp,
                           structural_violations=violations, check=None,
                           error=str(e))
    return LockVerdict(name=name, facts=facts, expectations=exp,
                       structural_violations=violations, check=check)


def verify_all(specs: dict | None = None, *, names: tuple = (),
               exhaustive: bool = False, episodes: int = 2,
               max_states: int = 200_000, model: bool = True,
               on_result=None) -> list:
    if specs is None:
        from repro.core.locks.specs import SPECS as specs
    picked = {n: a for n, a in specs.items() if not names or n in names}
    unknown = set(names) - set(picked)
    if unknown:
        raise KeyError(f"unknown lock(s): {sorted(unknown)} "
                       f"(have: {sorted(specs)})")
    out = []
    for n, author in picked.items():
        v = verify_lock(author, n, exhaustive=exhaustive, model=model,
                        episodes=episodes, max_states=max_states)
        out.append(v)
        if on_result is not None:
            on_result(v)
    return out


# ---------------------------------------------------------------------------
# The verified property matrix (terminal + RESULTS.md)
# ---------------------------------------------------------------------------
def matrix_columns() -> list:
    return ["lock", "doorway", "release", "spin", "footprint", "bypass",
            "model_check"]


def _cell_doorway(v: LockVerdict) -> str:
    g = v.facts.doorway_grade
    if g == "constant":
        return f"✓ ≤{v.facts.doorway.bound} ops"
    if g == "none":
        return "— none (not FCFS)"
    return "✗ declared" if v.expectations.get("doorway") == g \
        else f"✗ {g}"


def _cell_release(v: LockVerdict) -> str:
    g = v.facts.release_grade
    if g == "wait_free":
        return f"✓ wait-free ≤{v.facts.release.bound}"
    if g == "waits":
        return ("✓ bounded ≤{}, waits at {}".format(
            v.facts.release.bound, ",".join(v.facts.release.waits)))
    return "✗ declared" if v.expectations.get("release") == g \
        else f"✗ {g}"


def _cell_spin(v: LockVerdict) -> str:
    lv = v.facts.spin_level
    return {"own": "✓ own cell", "cell": "✓ per-waiter cell",
            "shared": "✗ declared shared" if v.expectations.get("spin")
            == "shared" else "✗ shared",
            "none": "— no waiting"}[lv]


def _cell_bypass(v: LockVerdict) -> str:
    if "bypass" not in v.expectations:
        return "—"
    b = v.expectations["bypass"]
    if v.check is None:             # structural-only run: declared, unproven
        return ("✗ declared unbounded" if b is None
                else f"declared ≤{b} (run `verify`)")
    seen = v.check.max_bypass
    seen_s = f"≥{seen}" if seen >= v.check.bypass_cap else str(seen)
    if b is None:
        return f"✗ declared unbounded (saw {seen_s})"
    return f"✓ ≤{b} (saw {seen_s})"


def matrix_rows(verdicts: list) -> list:
    rows = []
    for v in verdicts:
        if v.error is not None or v.facts is None:
            rows.append({"lock": v.name, "doorway": "✗ error",
                         "release": "—", "spin": "—", "footprint": "—",
                         "bypass": "—", "model_check": v.error or "—"})
            continue
        row = {
            "lock": v.name,
            "doorway": _cell_doorway(v),
            "release": _cell_release(v),
            "spin": _cell_spin(v),
            "footprint": f"✓ {v.facts.footprint} word(s)",
            "bypass": _cell_bypass(v),
            "model_check": (v.check.certificate if v.check is not None
                            else "—"),
        }
        if v.structural_violations:
            row["doorway"] = "✗ " + v.structural_violations[0]
        rows.append(row)
    return rows


def render_matrix(verdicts: list) -> str:
    """Terminal rendering (also used by ``repro.bench list
    --properties``)."""
    cols = matrix_columns()
    rows = matrix_rows(verdicts)
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows))
              for c in cols}
    lines = ["  ".join(c.ljust(widths[c]) for c in cols),
             "  ".join("-" * widths[c] for c in cols)]
    for r in rows:
        lines.append("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    return "\n".join(lines)
