"""Lock-program registry: compiled ``LockSpec``s for ``core.sim.machine``.

Locks are *authored* declaratively in ``core/locks/specs.py`` using the
phase DSL (``core/locks/dsl.py``) and *lowered* to the machine's
handler-table ``Program`` form by ``core/locks/compile.py`` — which
injects the shared NCS/CS-profile scaffolding every program used to
restate. Op semantics and result encodings live in one place: the
contract table at the top of ``core/sim/machine.py``.

``PROGRAMS[name](n_threads, ncs_max=..., cs_shared=...) -> Program`` is
the stable entry point consumed by ``core/sim/api.py``, the
``repro.bench`` sweep driver and the CLI; its signature is unchanged from
the pre-DSL hand-rolled tables (frozen as a differential-test oracle in
``tests/_legacy_programs.py``).
"""
from __future__ import annotations

from functools import partial

from repro.core.locks.compile import compile_spec, describe_spec
from repro.core.locks.specs import ABORTABLE_VARIANTS, NEW_VARIANTS, SPECS

__all__ = ["PROGRAMS", "NEW_VARIANTS", "ABORTABLE_VARIANTS",
           "describe_program"]

PROGRAMS = {name: partial(compile_spec, author, name=name)
            for name, author in SPECS.items()}


def describe_program(name: str, n_threads: int = 2) -> dict:
    """Phase/step/memory summary of a registered lock spec (used by
    ``python -m repro.bench list --programs``)."""
    return describe_spec(SPECS[name], n_threads)
