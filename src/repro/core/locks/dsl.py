"""Declarative lock-authoring DSL: ``LockSpec`` phase specs.

A lock is authored as named *phases* — ``doorway`` (the constant-time
arrival path), ``waiting`` (local spinning on a wait element), ``entry``
(admission into the critical section) and ``release`` — each a short list
of *steps*. A step is a function ``fn(c)`` receiving a :class:`Ctx` and
returning a :class:`StepOut`; it consumes ``c.res``, the result of the op
the previous step emitted, and emits the next op. Op semantics and result
encodings (CAS ``old * 2 + ok``, SPIN_EQ/SPIN_NE blocking, PARK_EQ park
costs, LOCKEDEMPTY == 1) are defined once, in the contract table at the
top of ``core/sim/machine.py`` — not here.

What the DSL removes relative to hand-rolled handler tables:

* **raw PCs** — steps are addressed by *label* (default: the step
  function's name); ``to="woke"`` instead of ``pc=4``. The compiler
  (``core/locks/compile.py``) assigns program counters.
* **magic addresses** — memory is *declared*: ``s.word("tail")`` for lock
  words (compiler-assigned addresses 0..3, NUMA-homed on node 0),
  ``s.per_thread("element")`` for per-thread wait elements (homed on the
  owning thread's node — the paper's 128B sequestering), ``s.array(...)``
  for global slot arrays.
* **copy-pasted scaffolding** — the NCS delay handler and the CS-profile
  handlers (``rw``/``ro``/``local``, paper §7.1) are injected by the
  compiler. A step enters the critical section with ``c.enter_cs()``; an
  episode ends with ``to=NCS``.
* **implicit instrumentation** — ``arrive=True`` marks doorway completion
  and ``admit=True`` marks CS admission (they feed the latency/fairness
  metrics and the admission log); the markers are explicit keywords, not
  buried flag tuples.

Control flow is data-flow, exactly as in the underlying machine: a step
branches with ``c.when(cond, then_out, else_out)``, which merges two
``StepOut``s component-wise with ``jnp.where``. Conditional register
updates are written the same way: ``c.r.succ = jnp.where(cond, a, b)``.

A complete lock in ~15 lines (see ``core/locks/specs.py`` for the zoo,
``examples/define_a_lock.py`` for a runnable walkthrough)::

    def ticket(s):
        tk, gr = s.word("ticket"), s.word("grant")
        s.regs("my")

        @s.step("doorway")
        def take(c):
            return c.op(FAA(tk, 1))             # falls through to `got`

        @s.step("doorway")
        def got(c):
            c.r.my = c.res
            return c.op(SPIN_EQ(gr, c.res), arrive=True)

        @s.step("entry")
        def granted(c):
            return c.enter_cs(admit=True)

        @s.step("release")
        def load_grant(c):
            return c.op(LOAD(gr))

        @s.step("release")
        def bump_grant(c):
            return c.op(STORE(gr, c.res + 1), to=NCS)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax.numpy as jnp

from repro.core.sim import machine as M

I32 = jnp.int32

#: Reserved jump target: episode complete, re-enter the injected NCS delay.
NCS = "ncs"

#: Phase taxonomy (paper's structure). ``doorway`` may be empty for
#: non-FCFS locks (TTAS has no constant-time doorway — that's the point).
#: ``abort`` holds the steps an impatient waiter runs after a timed wait
#: (``PARK_*_TIMEOUT``) gives up — they must restore queue integrity: an
#: aborted waiter leaves no live cell behind (tests/test_hostile.py).
PHASES = ("doorway", "waiting", "entry", "release", "abort")

# Address/value conventions — machine.py contract table.
CS_WORD, CS2_WORD, ELEM_BASE = 4, 5, 8
LOCKEDEMPTY = 1
MAX_LOCK_WORDS = CS_WORD


def _i(x) -> jnp.ndarray:
    return jnp.asarray(x, I32)


def _b(x) -> jnp.ndarray:
    return jnp.asarray(x, bool)


class OpExpr(NamedTuple):
    """One machine op: ``(kind, addr, a, b)``, fields int or traced i32.
    Semantics/result encoding: the contract table in ``core/sim/machine``."""
    kind: Any
    addr: Any
    a: Any = 0
    b: Any = 0


def LOAD(addr) -> OpExpr:
    return OpExpr(M.LOAD, addr)


def STORE(addr, value) -> OpExpr:
    return OpExpr(M.STORE, addr, value)


def XCHG(addr, value) -> OpExpr:
    return OpExpr(M.XCHG, addr, value)


def CAS(addr, expect, new) -> OpExpr:
    """Result is ``old * 2 + ok`` (machine.py contract table)."""
    return OpExpr(M.CAS, addr, expect, new)


def FAA(addr, delta) -> OpExpr:
    return OpExpr(M.FAA, addr, delta)


def SPIN_EQ(addr, value) -> OpExpr:
    return OpExpr(M.SPIN_EQ, addr, value)


def SPIN_NE(addr, value) -> OpExpr:
    return OpExpr(M.SPIN_NE, addr, value)


def PARK_EQ(addr, value) -> OpExpr:
    """Blocking wait with the park/unpark cost model (machine.py table)."""
    return OpExpr(M.PARK_EQ, addr, value)


def PARK_EQ_TIMEOUT(addr, value, timeout) -> OpExpr:
    """Abortable wait: PARK_EQ that gives up after ``timeout`` private
    cycles. Result packs like CAS: ``watched * 2 + ok`` — ok == 0 means
    the wait timed out and the spec's ``abort`` phase runs next."""
    return OpExpr(M.PARK_EQ_TIMEOUT, addr, value, timeout)


def PARK_NE_TIMEOUT(addr, value, timeout) -> OpExpr:
    """Abortable wait for the word to *differ* from ``value`` (timed
    SPIN_NE under the park cost model); result as PARK_EQ_TIMEOUT."""
    return OpExpr(M.PARK_NE_TIMEOUT, addr, value, timeout)


def DELAY(cycles) -> OpExpr:
    return OpExpr(M.DELAY, 0, cycles)


def NOP() -> OpExpr:
    return OpExpr(M.NOP, 0)


class Region:
    """A declared block of words. ``at(i)`` addresses the i-th word
    (accepts traced indices); ``translate(addr, src)`` maps an address in
    region ``src`` to the same offset here (queue locks keep parallel
    per-thread arrays — e.g. MCS's ``next``/``locked``)."""

    def __init__(self, name: str, base: int, size: int, homed: bool):
        self.name, self.base, self.size, self.homed = name, base, size, homed

    def at(self, i):
        return self.base + i

    def translate(self, addr, src: "Region"):
        return addr + (self.base - src.base)

    def __repr__(self):
        kind = "per-thread" if self.homed else "array"
        return f"Region({self.name}@{self.base}+{self.size}, {kind})"


class StepOut(NamedTuple):
    """What a step returns: the next op, the jump target (already resolved
    to a pc by the Ctx), and the arrive/admit instrumentation markers."""
    op: tuple
    pc: Any
    arrive: Any = False
    admit: Any = False


class Step(NamedTuple):
    label: str
    phase: str
    fn: Callable


class SpecError(ValueError):
    pass


class _Regs:
    """Attribute-style symbolic register file: ``c.r.succ = value`` lowers
    to ``regs.at[i].set(value)``; reads return ``regs[i]``. Conditional
    updates are data-flow: ``c.r.x = jnp.where(cond, a, b)``."""

    __slots__ = ("_arr", "_map")

    def __init__(self, arr, regmap):
        object.__setattr__(self, "_arr", arr)
        object.__setattr__(self, "_map", regmap)

    def _idx(self, name):
        if name.startswith("_"):        # protocol probes (__deepcopy__, ...)
            raise AttributeError(name)
        try:
            return self._map[name]
        except KeyError:
            raise SpecError(
                f"unknown register {name!r}; declare it with "
                f"s.regs({name!r}) (have: {sorted(self._map)})") from None

    def __getattr__(self, name):
        return self._arr[self._idx(name)]

    def __setattr__(self, name, value):
        arr = self._arr.at[self._idx(name)].set(_i(value))
        object.__setattr__(self, "_arr", arr)


class Ctx:
    """Per-step context: ``t`` (thread id), ``T`` (thread count), ``res``
    (previous op's result — encodings per the machine.py contract table),
    ``r`` (symbolic registers), ``rng`` (read-only per-thread xorshift
    word, consumed by the injected NCS handler)."""

    def __init__(self, *, t, T, res, regs, rng, regmap, labels,
                 fallthrough, cs1_op, cs2_pc):
        self.t, self.T, self.res, self.rng = t, T, res, rng
        self.r = _Regs(regs, regmap)
        self._labels = labels
        self._fallthrough = fallthrough
        self._cs1_op, self._cs2_pc = cs1_op, cs2_pc

    # -- jump-target resolution ---------------------------------------------
    def _pc(self, to):
        if to is None:
            if self._fallthrough is None:
                raise SpecError(
                    "last declared step cannot fall through; give an "
                    "explicit to= (e.g. to=NCS)")
            return self._fallthrough
        if isinstance(to, str):
            try:
                return self._labels[to]
            except KeyError:
                raise SpecError(
                    f"unknown label {to!r}; declared steps: "
                    f"{sorted(k for k in self._labels if k != NCS)}"
                ) from None
        return to                       # already a pc (merged / traced)

    # -- step outputs --------------------------------------------------------
    def op(self, op: OpExpr, to=None, arrive=False, admit=False) -> StepOut:
        """Emit ``op`` and jump to ``to`` (default: the next declared
        step; ``NCS`` ends the episode)."""
        return StepOut(op=tuple(op), pc=self._pc(to),
                       arrive=arrive, admit=admit)

    def enter_cs(self, admit=False, arrive=False) -> StepOut:
        """Enter the critical section: emits the first CS-profile op and
        routes through the compiler-injected CS scaffolding into the
        first ``release`` step."""
        return StepOut(op=self._cs1_op, pc=self._cs2_pc,
                       arrive=arrive, admit=admit)

    def when(self, cond, then: StepOut, other: StepOut, *,
             arrive=None, admit=None) -> StepOut:
        """Data-flow branch: merge two step outputs with ``jnp.where``.
        ``arrive``/``admit`` override the merged markers when given."""
        op = tuple(jnp.where(cond, _i(x), _i(y))
                   for x, y in zip(then.op, other.op))
        pc = jnp.where(cond, _i(then.pc), _i(other.pc))
        arr = (_b(arrive) if arrive is not None
               else jnp.where(cond, _b(then.arrive), _b(other.arrive)))
        adm = (_b(admit) if admit is not None
               else jnp.where(cond, _b(then.admit), _b(other.admit)))
        return StepOut(op=op, pc=pc, arrive=arr, admit=adm)


class LockSpec:
    """Builder handed to a spec author function ``def mylock(s): ...``.

    Declares memory regions (addresses are assigned eagerly, following the
    machine.py layout conventions: lock words 0..3, CS words 4/5, arrays
    from 8), symbolic registers, and the labelled steps of each phase.
    ``core/locks/compile.py`` lowers the collected spec to a ``Program``.
    """

    def __init__(self, name: str, n_threads: int):
        self.name = name
        self.T = n_threads
        self.steps: list[Step] = []
        self.regions: list[Region] = []
        self.words: dict[str, int] = {}
        self.inits: list[tuple] = []
        self.regmap: dict[str, int] = {}
        self.expectations: dict = {}
        self._next_word = 0
        self._array_top = ELEM_BASE

    # -- declared property expectations --------------------------------------
    def expect(self, **props) -> None:
        """Declare the paper-table properties this lock claims, checked
        *two-sided* against the static analyzer (``core/locks/cfg.py``)
        at compile time — a ticket lock must say ``spin="shared"``, and
        a stale declaration is as much an error as a false claim.

        Keys: ``doorway`` ("constant" / "none" / "unbounded"),
        ``release`` ("wait_free" / "waits" / "unbounded"), ``spin``
        ("own" / "cell" / "shared"), ``footprint`` (sequestered words
        per thread), ``bypass`` (admission-bypass bound, ``None`` for
        unbounded — certified by the small-scope model checker in
        ``core/locks/verify.py``, not at compile time). Undeclared
        specs get only the safety floor: loop-free doorway/release."""
        from repro.core.locks.cfg import validate_expectations
        merged = {**self.expectations, **props}
        validate_expectations(merged, self.name)
        self.expectations = merged

    # -- memory declarations -------------------------------------------------
    def word(self, name: str, init: int | None = None) -> int:
        """Declare a lock word (homed on node 0); returns its address."""
        if self._next_word >= MAX_LOCK_WORDS:
            raise SpecError(f"{self.name}: more than {MAX_LOCK_WORDS} lock "
                            "words (addresses 0..3 are reserved for them)")
        addr = self._next_word
        self._next_word += 1
        self.words[name] = addr
        if init is not None:
            self.init(addr, init)
        return addr

    def array(self, name: str, size: int, homed: bool = False,
              init: dict | None = None) -> Region:
        """Declare a block of ``size`` words above ``ELEM_BASE``.
        ``homed=True`` homes word ``base + i`` on thread ``i``'s NUMA node
        (only meaningful when ``size >= T``)."""
        r = Region(name, self._array_top, size, homed)
        self._array_top += size
        self.regions.append(r)
        for off, v in (init or {}).items():
            self.init(r.base + off, v)
        return r

    def per_thread(self, name: str, init: dict | None = None) -> Region:
        """A wait-element array with one word per thread, homed on the
        owning thread's node (the paper's sequestered-line layout)."""
        return self.array(name, self.T, homed=True, init=init)

    def init(self, addr: int, value: int) -> None:
        """Set an initial memory value (e.g. CLH's tail -> dummy node)."""
        self.inits.append((int(addr), int(value)))

    # -- registers -----------------------------------------------------------
    def regs(self, *names: str) -> tuple:
        """Declare symbolic registers, readable/writable as ``c.r.<name>``;
        returns their indices."""
        out = []
        for n in names:
            if n in self.regmap:
                raise SpecError(f"{self.name}: register {n!r} redeclared")
            self.regmap[n] = len(self.regmap)
            out.append(self.regmap[n])
        return tuple(out)

    # -- steps ---------------------------------------------------------------
    def step(self, phase: str, label: str | None = None):
        """Decorator registering a step in ``phase``. The label (default:
        the function name) is the jump target other steps use."""
        if phase not in PHASES:
            raise SpecError(f"{self.name}: unknown phase {phase!r} "
                            f"(must be one of {PHASES})")

        def deco(fn):
            lab = label or fn.__name__
            if lab == NCS or any(s.label == lab for s in self.steps):
                raise SpecError(f"{self.name}: duplicate/reserved step "
                                f"label {lab!r}")
            self.steps.append(Step(lab, phase, fn))
            return fn
        return deco

    # -- layout summary ------------------------------------------------------
    @property
    def n_mem(self) -> int:
        return self._array_top

    def home(self) -> tuple:
        """Per-word NUMA home thread (-1 => node 0), from the region
        declarations — replaces per-lock hand-built home tables."""
        home = [-1] * self.n_mem
        for r in self.regions:
            if r.homed:
                for t in range(min(r.size, self.T)):
                    home[r.base + t] = t
        return tuple(home)

    def validate(self) -> None:
        if not self.steps:
            raise SpecError(f"{self.name}: spec declares no steps")
        if not any(s.phase == "release" for s in self.steps):
            raise SpecError(f"{self.name}: spec has no release phase")
        if len(self.regmap) > 8:
            raise SpecError(f"{self.name}: more than 8 registers")

    def phase_summary(self) -> dict:
        out: dict = {p: [] for p in PHASES}
        for s in self.steps:
            out[s.phase].append(s.label)
        return out
