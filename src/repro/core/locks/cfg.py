"""Control-flow-graph lifting for ``LockSpec`` phase specs.

The paper's headline claims are *structural* — constant-time doorway and
release, local spinning on a single per-thread waiting element, one wait
element per thread — and the ``LockSpec`` DSL is exactly the IR to decide
them on: steps are labelled, phases are declared, memory is declared as
:class:`~repro.core.locks.dsl.Region` objects with homing, and branches
are explicit ``c.when(...)`` merges. This module recovers a per-phase
control-flow graph from a spec *without running the machine*, by
executing every step function once against a recording context
(:class:`SymCtx`) that

* hands out :class:`SymVal` symbols for ``c.t`` / ``c.res`` / register
  reads, so operand *provenance* survives the step body's arithmetic
  (``elem.at(c.t)`` classifies as the own sequestered cell, ``c.res`` as
  a pointer chase, ``cells.at(c.res % T)`` as a dynamic cell of the
  ``cells`` region);
* records **both** arms of every ``c.when`` instead of jnp-merging them
  (the DSL builds both ``StepOut``s eagerly — data-flow branching — so
  one execution per step surfaces every edge);
* degrades gracefully when a step body hands symbols to ``jnp.*``
  (``jnp.where`` on a ``SymVal`` consumes a concrete *witness* value via
  ``__jax_array__``): the whole extraction runs twice, with thread-id
  witnesses 0 and 1, and joining the two runs re-classifies opaque
  results (an address that shifts by exactly 1 with ``t`` is a
  thread-indexed cell; one that doesn't move is a fixed word).

On top of the CFG, :func:`analyze` computes the structural facts the
verifier (``core/locks/verify.py``) and the compile-time gate consume:

* **doorway** — is the pre-``arrive`` path loop-free, how many ops does
  the longest path complete before the arrive marker fires, and does it
  ever block;
* **release** — loop-free bound and whether any path waits (MCS's
  late-successor ``SPIN_NE`` vs the reciprocating lock's wait-free
  store/CAS tail);
* **spin locality** — every ``SPIN_*``/``PARK_*`` target classified
  ``own`` (homed region at index ``t``), ``cell`` (per-waiter dynamic or
  pointer-chased cell — single-spinner status is certified by the
  small-scope model checker), or ``shared`` (a lock word, or a
  waiting/entry loop that hammers one);
* **waiting footprint** — how many distinct per-thread sequestered words
  the spec ever touches (the paper's "one wait element per thread").

:func:`check_spec` compares the facts against the spec's *declared
expectations* (``s.expect(...)`` in the DSL): undeclared specs only get
the safety floor (doorway/release loop-freedom), declared ones are
checked two-sided — claiming less than is proven is as much an error as
claiming more, so declarations can't go stale.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

from repro.core.locks.dsl import (
    MAX_LOCK_WORDS, NCS, LockSpec, OpExpr, SpecError, Step,
)
from repro.core.sim import machine as M

__all__ = ["SymVal", "SpecCFG", "Edge", "OpFacts", "StructuralFacts",
           "build_cfg", "analyze", "check_spec", "EXPECT_KEYS",
           "BLOCKING_KINDS", "TIMED_KINDS", "KIND_NAMES"]

BLOCKING_KINDS = (M.SPIN_EQ, M.SPIN_NE, M.PARK_EQ,
                  M.PARK_EQ_TIMEOUT, M.PARK_NE_TIMEOUT)
TIMED_KINDS = (M.PARK_EQ_TIMEOUT, M.PARK_NE_TIMEOUT)
KIND_NAMES = {M.NOP: "NOP", M.LOAD: "LOAD", M.STORE: "STORE",
              M.XCHG: "XCHG", M.CAS: "CAS", M.FAA: "FAA",
              M.SPIN_EQ: "SPIN_EQ", M.SPIN_NE: "SPIN_NE",
              M.DELAY: "DELAY", M.PARK_EQ: "PARK_EQ",
              M.PARK_EQ_TIMEOUT: "PARK_EQ_TIMEOUT",
              M.PARK_NE_TIMEOUT: "PARK_NE_TIMEOUT"}

#: Pseudo-targets: the injected CS scaffolding and the episode end.
CS, END = "@cs", NCS


# ---------------------------------------------------------------------------
# Symbolic values
# ---------------------------------------------------------------------------
class SymVal:
    """A symbolic int32: ``const + tco * t`` when ``roots`` is empty
    (exact affine in the thread id), otherwise an opaque combination of
    the provenance roots in ``roots`` ("res", "reg:<name>", "t") with
    ``const`` kept as an additive *base hint* (so ``region.base + f(x)``
    still classifies into the region). ``wit`` is the concrete witness
    used when jnp consumes the symbol (``__jax_array__``)."""

    __slots__ = ("const", "tco", "roots", "wit")

    def __init__(self, const=0, tco=0, roots=frozenset(), wit=0):
        self.const, self.tco = int(const), int(tco)
        self.roots, self.wit = frozenset(roots), wit

    # -- provenance helpers --------------------------------------------------
    def _all_roots(self):
        return self.roots | ({"t"} if self.tco else frozenset())

    @staticmethod
    def _of(x):
        if isinstance(x, SymVal):
            return x
        if isinstance(x, bool) or not isinstance(x, int):
            return None                     # arrays / floats: opaque
        return SymVal(const=x, wit=x)

    def _wit_of(self, x):
        return x.wit if isinstance(x, SymVal) else x

    # -- affine-preserving arithmetic ----------------------------------------
    def __add__(self, o):
        so = self._of(o)
        if so is None:
            return _opaque_binop(self, o, "+")
        return SymVal(self.const + so.const, self.tco + so.tco,
                      self.roots | so.roots, _wit(self.wit, "+", so.wit))

    __radd__ = __add__

    def __sub__(self, o):
        so = self._of(o)
        if so is None:
            return _opaque_binop(self, o, "-")
        return SymVal(self.const - so.const, self.tco - so.tco,
                      self.roots | so.roots, _wit(self.wit, "-", so.wit))

    def __rsub__(self, o):
        so = self._of(o)
        if so is None:
            return _opaque_binop(o, self, "-")
        return SymVal(so.const - self.const, so.tco - self.tco,
                      self.roots | so.roots, _wit(so.wit, "-", self.wit))

    def __mul__(self, o):
        so = self._of(o)
        if (so is not None and not so.roots and so.tco == 0
                and not self.roots and self.tco == 0):
            return SymVal(self.const * so.const, 0, frozenset(),
                          _wit(self.wit, "*", so.wit))
        return _mix(self, o, "*")

    __rmul__ = __mul__

    # -- structure-losing ops: provenance union, base hint reset -------------
    def __mod__(self, o):
        return _mix(self, o, "%")

    def __rmod__(self, o):
        return _mix(o, self, "%")

    def __floordiv__(self, o):
        return _mix(self, o, "//")

    def __rfloordiv__(self, o):
        return _mix(o, self, "//")

    def __neg__(self):
        return SymVal(-self.const, -self.tco, self.roots,
                      _wit(0, "-", self.wit))

    # -- comparisons: symbolic booleans --------------------------------------
    def _cmp(self, o, opname):
        return _mix(self, o, opname)

    def __eq__(self, o):                    # noqa: they are symbolic
        return self._cmp(o, "==")

    def __ne__(self, o):
        return self._cmp(o, "!=")

    def __lt__(self, o):
        return self._cmp(o, "<")

    def __le__(self, o):
        return self._cmp(o, "<=")

    def __gt__(self, o):
        return self._cmp(o, ">")

    def __ge__(self, o):
        return self._cmp(o, ">=")

    def __hash__(self):                     # __eq__ is symbolic
        return id(self)

    def __bool__(self):
        raise SpecError(
            "step control flow must be data-flow (`c.when(...)`), not a "
            "Python `if` on a traced value")

    # -- jnp degradation ------------------------------------------------------
    def __jax_array__(self):
        import jax.numpy as jnp
        return jnp.asarray(self.wit)

    def __repr__(self):
        if not self.roots:
            return (f"Sym({self.const}"
                    + (f"+{self.tco}*t" if self.tco else "") + ")")
        return f"Sym({self.const}+f({','.join(sorted(self.roots))}))"


def _wit(a, opname, b):
    try:
        return {"+": lambda: a + b, "-": lambda: a - b,
                "*": lambda: a * b, "%": lambda: a % b if b else 0,
                "//": lambda: a // b if b else 0,
                "==": lambda: a == b, "!=": lambda: a != b,
                "<": lambda: a < b, "<=": lambda: a <= b,
                ">": lambda: a > b, ">=": lambda: a >= b}[opname]()
    except TypeError:                       # witness already an array
        return 0


def _roots_of(x):
    if isinstance(x, SymVal):
        return x._all_roots()
    if isinstance(x, int) and not isinstance(x, bool):
        return frozenset()
    return frozenset({"opaque"})


def _wit_any(x):
    return x.wit if isinstance(x, SymVal) else (
        x if isinstance(x, int) else 0)


def _mix(a, b, opname):
    """Structure-losing combination: keep provenance, drop the affine
    form and the base hint (a `%`/`*`/comparison invalidates both)."""
    return SymVal(0, 0, _roots_of(a) | _roots_of(b),
                  _wit(_wit_any(a), opname, _wit_any(b)))


def _opaque_binop(a, b, opname):
    """+/- with a non-int partner (array): keep the int side's base."""
    sa = SymVal._of(a)
    base = sa.const if isinstance(sa, SymVal) else 0
    return SymVal(base, 0, _roots_of(a) | _roots_of(b) | {"opaque"},
                  _wit(_wit_any(a), opname, _wit_any(b)))


# ---------------------------------------------------------------------------
# Recording context (the SymCtx mirror of dsl.Ctx)
# ---------------------------------------------------------------------------
class _SymOut:
    """Either a leaf (one emitted op + target) or a branch of two."""

    def __init__(self, op=None, to=None, arrive=False, admit=False,
                 branches=None):
        self.op, self.to = op, to
        self.arrive, self.admit = arrive, admit
        self.branches = branches

    def leaves(self):
        if self.branches is None:
            yield self
            return
        for br in self.branches:
            for leaf in br.leaves():
                yield _SymOut(op=leaf.op, to=leaf.to,
                              arrive=(self.arrive if self.arrive is not None
                                      else leaf.arrive),
                              admit=(self.admit if self.admit is not None
                                     else leaf.admit))


class _SymRegs:
    """Register file for the recorder: reads return fresh symbols (one
    per declared register — cross-step flow is deliberately cut, each
    step is analyzed in isolation), reads-after-write within one step
    return the written value."""

    __slots__ = ("_vals", "_map")

    def __init__(self, regmap):
        object.__setattr__(self, "_vals", {})
        object.__setattr__(self, "_map", regmap)

    def __getattr__(self, name):
        if name.startswith("_"):
            raise AttributeError(name)
        if name not in self._map:
            raise SpecError(
                f"unknown register {name!r}; declare it with "
                f"s.regs({name!r}) (have: {sorted(self._map)})")
        return self._vals.get(
            name, SymVal(roots=frozenset({f"reg:{name}"}), wit=0))

    def __setattr__(self, name, value):
        if name not in self._map:
            raise SpecError(
                f"unknown register {name!r}; declare it with "
                f"s.regs({name!r}) (have: {sorted(self._map)})")
        self._vals[name] = value


class SymCtx:
    """Recording mirror of :class:`~repro.core.locks.dsl.Ctx`: same
    surface (``t``/``T``/``res``/``r``/``rng``, ``op``/``when``/
    ``enter_cs``), but ops are recorded instead of lowered and *both*
    ``when`` arms are kept."""

    def __init__(self, spec: LockSpec, step: Step, fallthrough, t_wit: int):
        self.t = SymVal(tco=1, wit=t_wit, roots=frozenset())
        self.T = spec.T
        self.res = SymVal(roots=frozenset({"res"}), wit=0)
        self.rng = SymVal(roots=frozenset({"rng"}), wit=1)
        self.r = _SymRegs(spec.regmap)
        self._spec, self._step = spec, step
        self._labels = {s.label for s in spec.steps} | {NCS}
        self._fallthrough = fallthrough

    def _target(self, to):
        if to is None:
            if self._fallthrough is None:
                raise SpecError(
                    "last declared step cannot fall through; give an "
                    "explicit to= (e.g. to=NCS)")
            return self._fallthrough
        if isinstance(to, str):
            if to not in self._labels:
                raise SpecError(
                    f"unknown label {to!r}; declared steps: "
                    f"{sorted(k for k in self._labels if k != NCS)}")
            return to
        return "@dynamic"                   # raw/traced pc: CFG-opaque

    def op(self, op: OpExpr, to=None, arrive=False, admit=False):
        return _SymOut(op=op, to=self._target(to),
                       arrive=bool(arrive), admit=bool(admit))

    def enter_cs(self, admit=False, arrive=False):
        return _SymOut(op=None, to=CS, arrive=bool(arrive),
                       admit=bool(admit))

    def when(self, cond, then, other, *, arrive=None, admit=None):
        del cond                            # both arms recorded
        return _SymOut(branches=(then, other),
                       arrive=None if arrive is None else bool(arrive),
                       admit=None if admit is None else bool(admit))


# ---------------------------------------------------------------------------
# Operand classification and the CFG proper
# ---------------------------------------------------------------------------
class OperandClass(NamedTuple):
    """Where an op operand points: ``kind`` is ``word`` (a fixed lock /
    CS word), ``own`` (homed region at index exactly ``t``), ``cell``
    (region cell at a dynamic index, or the neighbour's cell), ``chase``
    (pointer value from ``res``/a register), or ``value`` (not an
    address-shaped operand)."""
    kind: str
    detail: str


def _region_of(spec: LockSpec, addr: int):
    for r in spec.regions:
        if r.base <= addr < r.base + r.size:
            return r
    return None


def _classify(spec: LockSpec, v0, v1) -> OperandClass:
    """Join the two probe runs (t witness 0 / 1) into one operand class.
    ``v0``/``v1`` are ints, SymVals, or opaque arrays."""
    def as_pair(v):
        if isinstance(v, SymVal):
            return v
        if isinstance(v, int) and not isinstance(v, bool):
            return SymVal(const=v, wit=v)
        return None                         # opaque array

    s0, s1 = as_pair(v0), as_pair(v1)
    if s0 is not None and not s0.roots:     # exact affine const + tco*t
        base, tco = s0.const, s0.tco
        if tco == 0:
            if base < MAX_LOCK_WORDS:
                name = next((n for n, a in spec.words.items() if a == base),
                            str(base))
                return OperandClass("word", name)
            r = _region_of(spec, base)
            if r is not None:
                return OperandClass("cell", f"{r.name}[{base - r.base}]")
            return OperandClass("word", str(base))
        r = _region_of(spec, base)
        if tco == 1 and r is not None and r.base == base and r.homed:
            return OperandClass("own", f"{r.name}[t]")
        if r is not None:
            return OperandClass("cell", f"{r.name}[{base - r.base}+{tco}t]")
        return OperandClass("cell", f"{base}+{tco}t")
    if s0 is not None:                      # provenance-tracked, non-affine
        r = _region_of(spec, s0.const)
        if r is not None and s0.const == r.base:
            return OperandClass("cell", f"{r.name}[dyn]")
        roots = ",".join(sorted(s0.roots)) or "dyn"
        return OperandClass("chase", roots)
    # fully opaque (jnp degradation): join the concrete witnesses
    w0 = int(getattr(v0, "item", lambda: v0)())
    w1 = int(getattr(v1, "item", lambda: v1)())
    if w0 == w1:
        return _classify(spec, w0, w0)
    if w1 - w0 == 1:
        r = _region_of(spec, w0)
        if r is not None and r.base == w0 and r.homed:
            return OperandClass("own", f"{r.name}[t]")
        if r is not None:
            return OperandClass("cell", f"{r.name}[{w0 - r.base}+t]")
    return OperandClass("chase", "opaque")


class OpFacts(NamedTuple):
    kind: int
    addr: OperandClass
    value: OperandClass | None      # classified stored value (publishes)
    blocking: bool
    timed: bool

    def describe(self):
        k = KIND_NAMES.get(self.kind, str(self.kind))
        return f"{k}({self.addr.detail})"


class Edge(NamedTuple):
    src: str
    dst: str                        # a step label, ``@cs`` or ``ncs``
    op: OpFacts | None              # None for ``enter_cs`` edges
    arrive: bool
    admit: bool


@dataclass
class SpecCFG:
    spec: LockSpec
    edges: list = field(default_factory=list)
    phase: dict = field(default_factory=dict)       # label -> phase
    entry: str = ""

    def out(self, label: str):
        return [e for e in self.edges if e.src == label]

    def phase_nodes(self, *phases: str):
        return [s.label for s in self.spec.steps if s.phase in phases]

    def subgraph_cycle(self, nodes) -> list | None:
        """Return one cycle (as a label path) within ``nodes``, or None."""
        nodeset = set(nodes)
        adj = {n: sorted({e.dst for e in self.out(n) if e.dst in nodeset})
               for n in nodes}
        WHITE, GREY, BLACK = 0, 1, 2
        color = dict.fromkeys(nodes, WHITE)
        stack: list = []

        def dfs(n):
            color[n] = GREY
            stack.append(n)
            for m in adj[n]:
                if color[m] == GREY:
                    return stack[stack.index(m):] + [m]
                if color[m] == WHITE:
                    cyc = dfs(m)
                    if cyc:
                        return cyc
            stack.pop()
            color[n] = BLACK
            return None

        for n in nodes:
            if color[n] == WHITE:
                cyc = dfs(n)
                if cyc:
                    return cyc
        return None

    def longest_path(self, nodes, sources) -> int:
        """Longest node-count path inside the (acyclic) ``nodes``
        subgraph starting from ``sources``."""
        nodeset = set(nodes)
        memo: dict = {}

        def depth(n):
            if n in memo:
                return memo[n]
            memo[n] = 1                      # cycle guard (caller checked)
            best = 1
            for e in self.out(n):
                if e.dst in nodeset:
                    best = max(best, 1 + depth(e.dst))
            memo[n] = best
            return best

        return max((depth(s) for s in sources if s in nodeset), default=0)


def build_cfg(author_or_spec, n_threads: int = 4,
              name: str | None = None) -> SpecCFG:
    """Lift a spec (or author function) to its control-flow graph by
    running every step once per thread-witness against :class:`SymCtx`."""
    from repro.core.locks.compile import build_spec
    spec = (author_or_spec if isinstance(author_or_spec, LockSpec)
            else build_spec(author_or_spec, n_threads, name))

    def one_run(t_wit: int):
        out = []
        for i, st in enumerate(spec.steps):
            fallthrough = (spec.steps[i + 1].label
                           if i + 1 < len(spec.steps) else None)
            c = SymCtx(spec, st, fallthrough, t_wit)
            try:
                res = st.fn(c)
            except SpecError as e:
                raise SpecError(f"{spec.name}.{st.label}: {e}") from e
            if res is None:
                raise SpecError(
                    f"{spec.name}.{st.label}: step returned None (must "
                    "return c.op/c.when/c.enter_cs)")
            out.append((st, list(res.leaves())))
        return out

    run0, run1 = one_run(0), one_run(1)
    cfg = SpecCFG(spec=spec, entry=spec.steps[0].label,
                  phase={s.label: s.phase for s in spec.steps})
    for (st, leaves0), (_, leaves1) in zip(run0, run1):
        if len(leaves0) != len(leaves1):
            raise SpecError(f"{spec.name}.{st.label}: control flow "
                            "depends on the thread id witness")
        for l0, l1 in zip(leaves0, leaves1):
            if l0.op is None:               # enter_cs
                cfg.edges.append(Edge(st.label, CS, None,
                                      bool(l0.arrive), bool(l0.admit)))
                continue
            kind = int(l0.op.kind)
            addr = _classify(spec, l0.op.addr, l1.op.addr)
            value = None
            if kind in (M.STORE, M.XCHG):
                value = _classify(spec, l0.op.a, l1.op.a)
            elif kind == M.CAS:
                value = _classify(spec, l0.op.b, l1.op.b)
            facts = OpFacts(kind=kind, addr=addr, value=value,
                            blocking=kind in BLOCKING_KINDS,
                            timed=kind in TIMED_KINDS)
            cfg.edges.append(Edge(st.label, l0.to, facts,
                                  bool(l0.arrive), bool(l0.admit)))
    return cfg


# ---------------------------------------------------------------------------
# Structural analyses
# ---------------------------------------------------------------------------
@dataclass
class PhaseFacts:
    present: bool
    loop: list | None           # one offending cycle (labels), if any
    bound: int | None           # max ops completed on any path (if a DAG)
    waits: list                 # step labels emitting blocking ops

    @property
    def loop_free(self) -> bool:
        return self.loop is None

    def grade(self) -> str:
        if not self.present:
            return "none"
        if not self.loop_free:
            return "unbounded"
        return "waits" if self.waits else "constant"


@dataclass
class StructuralFacts:
    """Everything the gate / matrix needs, decided from the CFG alone."""
    cfg: SpecCFG
    doorway: PhaseFacts
    release: PhaseFacts
    spin_level: str             # "own" | "cell" | "shared" | "none"
    spin_ops: list              # (step label, OpFacts)
    spin_shared_loop: list | None   # loop hammering a lock word, if any
    footprint: int
    footprint_regions: list

    @property
    def doorway_grade(self):
        # the op emitted *with* the arrive marker runs after the marker
        # fires, so a blocking op there (ticket's SPIN_EQ) is the first
        # waiting-phase op, not a doorway cost
        return self.doorway.grade()

    @property
    def release_grade(self):
        g = self.release.grade()
        return {"constant": "wait_free"}.get(g, g)


def analyze(author_or_spec, n_threads: int = 4,
            name: str | None = None) -> StructuralFacts:
    cfg = (author_or_spec if isinstance(author_or_spec, SpecCFG)
           else build_cfg(author_or_spec, n_threads, name))
    spec = cfg.spec

    # --- doorway: the pre-arrive path --------------------------------------
    dnodes = cfg.phase_nodes("doorway")
    dloop = cfg.subgraph_cycle(dnodes) if dnodes else None
    dbound = None
    dwaits = []
    if dnodes and dloop is None:
        entry = [cfg.entry] if cfg.phase.get(cfg.entry) == "doorway" \
            else dnodes[:1]
        # ops completed before `arrive` = doorway steps run minus the
        # arriving one (its op executes after the marker is recorded)
        dbound = max(cfg.longest_path(dnodes, entry) - 1, 0)
        for n in dnodes:
            for e in cfg.out(n):
                if (e.op is not None and e.op.blocking and not e.arrive
                        and e.dst in set(dnodes)):
                    dwaits.append(n)
    doorway = PhaseFacts(bool(dnodes), dloop, dbound, sorted(set(dwaits)))

    # --- release ------------------------------------------------------------
    rnodes = cfg.phase_nodes("release")
    rloop = cfg.subgraph_cycle(rnodes)
    rbound = cfg.longest_path(rnodes, rnodes) if rloop is None else None
    rwaits = sorted({n for n in rnodes for e in cfg.out(n)
                     if e.op is not None and e.op.blocking})
    release = PhaseFacts(bool(rnodes), rloop, rbound, rwaits)

    # --- spin locality ------------------------------------------------------
    spin_ops = [(e.src, e.op) for e in cfg.edges
                if e.op is not None and e.op.blocking]
    levels = set()
    for _, op in spin_ops:
        levels.add({"own": "own", "cell": "cell", "chase": "cell",
                    "word": "shared"}[op.addr.kind])
    # an active-spin loop (waiting/entry cycle re-issuing ops on a lock
    # word) is global spinning even without a SPIN op on the word itself
    wenodes = cfg.phase_nodes("waiting", "entry")
    shared_loop = None
    cyc = cfg.subgraph_cycle(wenodes)
    if cyc is not None:
        cycset = set(cyc)
        for n in cyc:
            for e in cfg.out(n):
                if (e.dst in cycset and e.op is not None
                        and e.op.addr.kind == "word"
                        and e.op.kind not in (M.DELAY, M.NOP)):
                    shared_loop = cyc
    if shared_loop is not None:
        levels.add("shared")
    order = ("shared", "cell", "own")
    spin_level = next((x for x in order if x in levels), "none")

    # --- waiting footprint: distinct sequestered per-thread words -----------
    regions = set()
    for e in cfg.edges:
        if e.op is None:
            continue
        for cls in (e.op.addr, e.op.value):
            if cls is not None and cls.kind == "own":
                regions.add(cls.detail.split("[")[0])
    facts = StructuralFacts(
        cfg=cfg, doorway=doorway, release=release, spin_level=spin_level,
        spin_ops=spin_ops, spin_shared_loop=shared_loop,
        footprint=len(regions), footprint_regions=sorted(regions))
    return facts


# ---------------------------------------------------------------------------
# Declared expectations vs proven facts (the compile-time gate)
# ---------------------------------------------------------------------------
EXPECT_KEYS = ("doorway", "release", "spin", "footprint", "bypass")
_DOORWAY_VALUES = ("constant", "none", "unbounded")
_RELEASE_VALUES = ("wait_free", "waits", "unbounded")
_SPIN_VALUES = ("own", "cell", "shared")


def validate_expectations(exp: dict, name: str = "spec") -> None:
    for k in exp:
        if k not in EXPECT_KEYS:
            raise SpecError(f"{name}: unknown expectation {k!r} "
                            f"(must be one of {EXPECT_KEYS})")
    if "doorway" in exp and exp["doorway"] not in _DOORWAY_VALUES:
        raise SpecError(f"{name}: doorway= must be one of "
                        f"{_DOORWAY_VALUES}, got {exp['doorway']!r}")
    if "release" in exp and exp["release"] not in _RELEASE_VALUES:
        raise SpecError(f"{name}: release= must be one of "
                        f"{_RELEASE_VALUES}, got {exp['release']!r}")
    if "spin" in exp and exp["spin"] not in _SPIN_VALUES:
        raise SpecError(f"{name}: spin= must be one of "
                        f"{_SPIN_VALUES}, got {exp['spin']!r}")
    if "footprint" in exp and not isinstance(exp["footprint"], int):
        raise SpecError(f"{name}: footprint= must be an int")
    if "bypass" in exp and not (exp["bypass"] is None
                                or isinstance(exp["bypass"], int)):
        raise SpecError(f"{name}: bypass= must be an int or None")


def check_spec(facts: StructuralFacts,
               expectations: dict | None = None) -> list:
    """Compare structural facts against the spec's declared expectations.

    Returns a list of violation strings (each with phase/label
    provenance). Undeclared specs get only the safety floor: a loop in
    the doorway or release phase is an error unless explicitly declared
    ``doorway="unbounded"`` / ``release="unbounded"``. Declared keys are
    checked *two-sided* — a declaration weaker than what is proven is a
    stale declaration, also an error."""
    spec = facts.cfg.spec
    exp = dict(expectations if expectations is not None
               else getattr(spec, "expectations", {}) or {})
    validate_expectations(exp, spec.name)
    out = []

    # safety floor: constant-time doorway/release unless opted out
    if not facts.doorway.loop_free and exp.get("doorway") != "unbounded":
        out.append(
            "doorway phase has a loop ({}) — the paper's constant-time "
            "doorway is the default contract; declare "
            "s.expect(doorway=\"unbounded\") to opt out".format(
                " -> ".join(facts.doorway.loop)))
    if not facts.release.loop_free and exp.get("release") != "unbounded":
        out.append(
            "release phase has a loop ({}) — declare "
            "s.expect(release=\"unbounded\") to opt out".format(
                " -> ".join(facts.release.loop)))

    # two-sided declaration checks
    if "doorway" in exp and exp["doorway"] != facts.doorway_grade:
        out.append(
            f"declared doorway={exp['doorway']!r} but analysis proves "
            f"{facts.doorway_grade!r}"
            + (f" (loop {' -> '.join(facts.doorway.loop)})"
               if facts.doorway.loop else ""))
    if "release" in exp and exp["release"] != facts.release_grade:
        detail = ""
        if facts.release.loop:
            detail = f" (loop {' -> '.join(facts.release.loop)})"
        elif facts.release.waits:
            detail = f" (waits at {', '.join(facts.release.waits)})"
        out.append(
            f"declared release={exp['release']!r} but analysis proves "
            f"{facts.release_grade!r}{detail}")
    if "spin" in exp and facts.spin_level != "none" \
            and exp["spin"] != facts.spin_level:
        culprits = [f"{lab}: {op.describe()}" for lab, op in facts.spin_ops
                    if {"own": "own", "cell": "cell", "chase": "cell",
                        "word": "shared"}[op.addr.kind] == facts.spin_level]
        if facts.spin_shared_loop and facts.spin_level == "shared":
            culprits.append("active-spin loop "
                            + " -> ".join(facts.spin_shared_loop))
        out.append(
            f"declared spin={exp['spin']!r} but analysis proves "
            f"{facts.spin_level!r} ({'; '.join(culprits)})")
    if "footprint" in exp and exp["footprint"] != facts.footprint:
        out.append(
            f"declared footprint={exp['footprint']} but the spec touches "
            f"{facts.footprint} sequestered per-thread word(s) "
            f"({', '.join(facts.footprint_regions) or 'none'})")
    return out


def gate(author_or_spec, n_threads: int = 4,
         name: str | None = None) -> StructuralFacts:
    """The eager compile-time pass: analyze and raise ``SpecError`` on
    the first violation, with the spec name as provenance prefix."""
    facts = analyze(author_or_spec, n_threads, name)
    violations = check_spec(facts)
    if violations:
        raise SpecError(f"{facts.cfg.spec.name}: " + violations[0])
    return facts
