"""Reciprocating Lock — host runtime port (paper Listing 1).

Used *for real* by the framework: the multi-threaded data pipeline and the
async checkpoint writer synchronize with this lock. Structure is
line-faithful to Listing 1:

* one ``Arrivals`` word; arriving threads push their thread-local wait
  element with a single exchange (constant-time doorway),
* ownership relayed through the detached entry segment via ``Gate``,
  propagating the end-of-segment (possibly *zombie*) element address,
* constant-time release: Gate store | CAS-to-unlocked | detach-exchange.

Waiting uses an Event per wait element ("polite" waiting — the paper §8
notes constant-time paths make the algorithm amenable to park/unpark-style
primitives; ``Event`` is CPython's analogue). One singleton element per
thread in TLS suffices (a thread waits on at most one lock at a time), and
the element is reusable across any number of locks — the paper's
space-complexity point.

Atomic primitives come through the unified ``Atomics`` protocol
(``core/runtime/atomics.py``) — the same interface the measured Pallas
backend implements in-kernel — so the lock body is substrate-agnostic:
it allocates its one ``Arrivals`` word from whatever implementation is
injected (default: the process-wide host implementation).
"""
from __future__ import annotations

import threading

from repro.core.runtime.atomics import Atomics, host_atomics

_LOCKEDEMPTY = "LOCKEDEMPTY"           # the paper's tagged-1 encoding
_tls = threading.local()


class WaitElement:
    __slots__ = ("gate", "event")

    def __init__(self):
        self.gate = None
        self.event = threading.Event()

    def prepare(self):
        self.gate = None
        self.event.clear()

    def open(self, eos) -> None:       # Gate.store(eos) + wake
        self.gate = eos
        self.event.set()

    def await_gate(self):
        self.event.wait()
        return self.gate


def _element() -> WaitElement:
    e = getattr(_tls, "element", None)
    if e is None:
        e = _tls.element = WaitElement()
    return e


class ReciprocatingLock:
    """Context-manager mutex. Context (succ, eos) is kept per-thread
    (legacy-interface style — the paper's TLS option)."""

    def __init__(self, atomics: Atomics | None = None):
        self._arrivals = (atomics or host_atomics()).ref(None)
        self._ctx = threading.local()

    # -- Acquire (Listing 1 L14-47) ----------------------------------------
    def acquire(self) -> None:
        e = _element()
        e.prepare()                                     # L17
        tail = self._arrivals.exchange(e)               # L20 push
        succ, eos = None, e                             # L18-19
        if tail is not None:                            # L22 contention
            succ = None if tail is _LOCKEDEMPTY else tail   # L25 coerce
            eos = e.await_gate()                        # L28-32 wait
            assert eos is not None
            if succ is eos:                             # L36 terminus
                succ = None                             # L37 quash
                eos = _LOCKEDEMPTY                      # L39
        self._ctx.succ, self._ctx.eos = succ, eos

    # -- Release (Listing 1 L50-77) ------------------------------------------
    def release(self) -> None:
        succ, eos = self._ctx.succ, self._ctx.eos
        if succ is not None:                            # L53 entry segment
            succ.open(eos)                              # L58
            return
        if self._arrivals.compare_exchange(eos, None):  # L66 fast unlock
            return
        w = self._arrivals.exchange(_LOCKEDEMPTY)       # L73 detach
        assert w is not None and w is not _LOCKEDEMPTY
        w.open(eos)                                     # L76

    # -- pythonic sugar --------------------------------------------------------
    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked_hint(self) -> bool:
        return self._arrivals.load() is not None
