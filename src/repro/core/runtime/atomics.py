"""One ``Atomics`` interface, host and device implementations.

The runtime lock ports (``core/runtime/reciprocating.py``) and the
measured Pallas backend (``core/locks/pallas_backend.py``) both need
the same primitive set — load / store / exchange / compare_exchange /
fetch_add — against very different substrates:

* **Host** (:class:`HostAtomics`) — CPython exposes no user-level HW
  atomics, so :class:`AtomicRef` emulates them with a per-ref internal
  mutex (documented deviation — see DESIGN.md §L1). The *algorithmic
  structure* of the locks built on top (single-word state, segments,
  zombie end-of-segment, bounded bypass) is exactly the paper's; these
  runtime ports synchronize the framework's data pipeline and
  checkpoint writer for real.
* **Device** (:class:`PallasAtomics`) — in-kernel read-modify-writes on
  a Pallas memory ref. In ``interpret`` mode (the CPU fallback CI runs
  everywhere) grid programs execute sequentially, so a plain
  read-modify-write *is* linearizable and the jax interpreter's partial
  ``pl.atomic_*`` coverage (only ADD/MAX/MIN discharge; XCHG/CAS raise
  ``NotImplementedError``) never bites. On a real accelerator the same
  interface lowers to ``pl.atomic_*`` where the primitive exists and to
  a test-and-set guard-lock splice where it does not (``atomic_cas``
  only binds scalar refs, so dynamic-index CAS goes through the guard).

Both implementations answer to the same :class:`Atomics` protocol, so a
lock port is written once against the interface and the substrate is an
injection site — satellite of the sim->silicon tentpole (ISSUE 10).
"""
from __future__ import annotations

import threading


class AtomicRef:
    """A single shared word with wait-free-style primitives (host cell)."""
    __slots__ = ("_v", "_m")

    def __init__(self, value=None):
        self._v = value
        self._m = threading.Lock()

    def load(self):
        return self._v

    def store(self, value) -> None:
        with self._m:
            self._v = value

    def exchange(self, value):
        with self._m:
            old, self._v = self._v, value
            return old

    def compare_exchange(self, expect, value) -> bool:
        with self._m:
            if self._v is expect or self._v == expect:
                self._v = value
                return True
            return False

    def fetch_add(self, delta: int) -> int:
        with self._m:
            old = self._v
            self._v = old + delta
            return old


class Atomics:
    """The shared interface: allocate cells (host side) or operate on a
    Pallas ref in-kernel (device side). Implementations provide one of
    the two surfaces; ``ref()`` is the host allocation entry the runtime
    lock ports use."""

    def ref(self, value=None) -> AtomicRef:
        raise NotImplementedError


class HostAtomics(Atomics):
    """Host implementation: mutex-emulated :class:`AtomicRef` cells."""

    def ref(self, value=None) -> AtomicRef:
        return AtomicRef(value)


_HOST = HostAtomics()


def host_atomics() -> HostAtomics:
    """The process-wide host implementation (stateless — one suffices)."""
    return _HOST


class PallasAtomics(Atomics):
    """Device implementation: in-kernel atomics over a Pallas ref.

    Methods take ``(ref, idx, ...)`` with traced ``idx`` and values and
    return the *old* word, mirroring the machine's op results. With
    ``interpret=True`` every primitive is a plain read-modify-write —
    linearizable because interpret mode executes grid programs
    sequentially. With ``interpret=False`` the maskable primitives use
    ``pl.atomic_*`` directly and the composite ones (XCHG/CAS at a
    dynamic index) splice through a per-word exclusive window built on
    ``pl.atomic_xchg`` over a reserved guard word (index ``guard_idx``
    in the same ref, conventionally the kernel's dedicated guard slot).
    """

    def __init__(self, interpret: bool = True, guard_idx: int = 0):
        self.interpret = interpret
        self.guard_idx = guard_idx

    # -- exclusive window (device mode only) --------------------------------
    def _lock_guard(self, ref):
        import jax
        from jax.experimental import pallas as pl
        import jax.numpy as jnp
        gi = jnp.int32(self.guard_idx)

        def body(_):
            return pl.atomic_xchg(ref, (gi,), jnp.int32(1))
        # spin until the exchange returns 0 (we own the window)
        jax.lax.while_loop(lambda got: got != 0, body,
                           pl.atomic_xchg(ref, (gi,), jnp.int32(1)))

    def _unlock_guard(self, ref):
        from jax.experimental import pallas as pl
        import jax.numpy as jnp
        pl.atomic_xchg(ref, (jnp.int32(self.guard_idx),), jnp.int32(0))

    # -- primitives ----------------------------------------------------------
    def load(self, ref, idx):
        return ref[idx]

    def store(self, ref, idx, value) -> None:
        ref[idx] = value

    def exchange(self, ref, idx, value):
        if self.interpret:
            old = ref[idx]
            ref[idx] = value
            return old
        from jax.experimental import pallas as pl
        return pl.atomic_xchg(ref, (idx,), value)

    def fetch_add(self, ref, idx, delta):
        if self.interpret:
            old = ref[idx]
            ref[idx] = old + delta
            return old
        from jax.experimental import pallas as pl
        return pl.atomic_add(ref, (idx,), delta)

    def compare_exchange(self, ref, idx, expect, new):
        """Returns the old value (caller derives ``ok = old == expect``)."""
        import jax.numpy as jnp
        if self.interpret:
            old = ref[idx]
            ref[idx] = jnp.where(old == expect, new, old)
            return old
        # pl.atomic_cas binds only scalar refs — dynamic-index CAS goes
        # through the guard-lock exclusive window.
        self._lock_guard(ref)
        old = ref[idx]
        ref[idx] = jnp.where(old == expect, new, old)
        self._unlock_guard(ref)
        return old

    def rmw(self, ref, idx, kind, a, b):
        """Generic machine-op read-modify-write with a *traced* kind:
        the effect table of ``core/sim/machine.py`` (STORE/XCHG write
        ``a``, FAA adds ``a``, CAS writes ``b`` iff ``old == a``,
        loads/waits leave the word) selected data-flow-style. Returns
        the old value. This is the one primitive the measured kernel
        needs per micro-op."""
        import jax.numpy as jnp
        from repro.core.sim import machine as M
        if not self.interpret:
            self._lock_guard(ref)
        old = ref[idx]
        cas_ok = (kind == M.CAS) & (old == a)
        newval = jnp.where(kind == M.STORE, a,
                 jnp.where(kind == M.XCHG, a,
                 jnp.where(kind == M.FAA, old + a,
                 jnp.where(cas_ok, b, old))))
        ref[idx] = newval
        if not self.interpret:
            self._unlock_guard(ref)
        return old
