"""Host-side atomic primitives for the runtime lock ports.

CPython exposes no user-level HW atomics, so ``AtomicRef`` emulates
``exchange`` / ``compare_exchange`` / ``fetch_add`` with a per-ref internal
mutex (documented deviation — see DESIGN.md §L1). The *algorithmic
structure* of the locks built on top (single-word state, segments, zombie
end-of-segment, bounded bypass) is exactly the paper's; these runtime ports
synchronize the framework's data pipeline and checkpoint writer for real.
"""
from __future__ import annotations

import threading


class AtomicRef:
    """A single shared word with wait-free-style primitives."""
    __slots__ = ("_v", "_m")

    def __init__(self, value=None):
        self._v = value
        self._m = threading.Lock()

    def load(self):
        return self._v

    def store(self, value) -> None:
        with self._m:
            self._v = value

    def exchange(self, value):
        with self._m:
            old, self._v = self._v, value
            return old

    def compare_exchange(self, expect, value) -> bool:
        with self._m:
            if self._v is expect or self._v == expect:
                self._v = value
                return True
            return False

    def fetch_add(self, delta: int) -> int:
        with self._m:
            old = self._v
            self._v = old + delta
            return old
