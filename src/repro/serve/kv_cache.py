"""Paged KV cache: a block-table pool shared by the serving stack.

This is the memory half of the serving architecture (SERVING.md §2): KV
state lives in fixed-size *blocks* drawn from one pool; a request owns a
*block table* — an ordered list of block ids covering positions
``[i*block_size, (i+1)*block_size)``. The pool tracks three disjoint
populations over the same id space:

* **free** blocks — unowned, immediately allocatable;
* **pinned** blocks — referenced by ≥1 live request (refcounted, never
  evicted); full prefix blocks may be pinned by several requests at once
  (copy-free prefix sharing — the KV of a token depends only on the token
  and its absolute position, so identical prefixes have identical blocks);
* **cached** blocks — refcount 0 but retained under a ``(prefix_id,
  block_idx)`` key in LRU order; the prefix cache proper. Allocation
  evicts from the LRU head when the free list is empty.

The same class serves two clients with two views of the same bookkeeping:

* the **model engine** (`serve/engine.py`) uses the id-level API
  (``alloc`` / ``share`` / ``release``) and keeps the actual device
  arrays, indexed by block id, next to the jitted decode step
  (``models/decode.py::paged_decode_step`` gathers by block table);
* the **discrete-time simulator** (`serve/scheduler.py`) uses the
  occupancy API (``insert`` / ``hit_fraction`` / ``touch_decode``) to
  model residency decay without any arrays — subsuming the old
  ``PrefixCachePool``. Because both views mutate one LRU, the residency
  numbers the sim reports are claims about this code, not a look-alike.

Block id 0 can be reserved as a *null block* (``reserve_null=True``): the
engine points empty batch slots' tables at it so a fixed-shape jitted
decode step has somewhere harmless to scatter garbage (SERVING.md §3).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass


class KVPoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied even after evicting
    every unpinned cached block."""


@dataclass
class _BlockMeta:
    refcount: int = 0
    key: tuple | None = None        # (prefix_id, block_idx) if cached


@dataclass
class PoolStats:
    allocs: int = 0
    evictions: int = 0
    shared_hits: int = 0            # blocks served from the prefix cache
    exhausted: int = 0

    def to_dict(self) -> dict:
        return {"allocs": self.allocs, "evictions": self.evictions,
                "shared_hits": self.shared_hits,
                "exhausted": self.exhausted}


class PagedKVPool:
    """LRU block pool keyed by ``(prefix_id, block_idx)`` (SERVING.md §2)."""

    def __init__(self, capacity_blocks: int, reserve_null: bool = False,
                 evict_callback=None):
        if capacity_blocks < 1 + int(reserve_null):
            raise ValueError("pool needs at least one allocatable block")
        self.cap = capacity_blocks
        self.null_block: int | None = 0 if reserve_null else None
        first = 1 if reserve_null else 0
        self._free: list = list(range(capacity_blocks - 1, first - 1, -1))
        self._meta: dict = {}                   # block_id -> _BlockMeta
        self._cached: OrderedDict = OrderedDict()   # key -> block_id (LRU)
        self._owned: dict = {}                  # owner -> [block_id, ...]
        #: called with the ``(prefix_id, block_idx)`` key whenever a
        #: cached prefix block is dropped from the pool (LRU eviction) —
        #: the hook a fleet router uses to keep its global prefix index
        #: coherent with per-replica residency (SERVING.md §8). Fires
        #: mid-allocation: the callback must not re-enter the pool.
        self.evict_callback = evict_callback
        self.stats = PoolStats()

    # -- capacity accounting --------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached(self) -> int:
        """Blocks retained only by the prefix cache (evictable)."""
        return sum(1 for bid in self._cached.values()
                   if self._meta[bid].refcount == 0)

    @property
    def n_pinned(self) -> int:
        return sum(1 for m in self._meta.values() if m.refcount > 0)

    def check(self) -> None:
        """Internal invariants (exercised by tests/test_serve.py)."""
        live = set(self._free)
        assert len(live) == len(self._free), "double-free"
        for bid, m in self._meta.items():
            assert bid not in live, f"block {bid} both free and live"
            assert m.refcount >= 0
            if m.key is not None:
                assert self._cached.get(m.key) == bid
        for key, bid in self._cached.items():
            assert self._meta[bid].key == key
        n_meta = len([m for m in self._meta.values()
                      if m.refcount > 0 or m.key is not None])
        n_null = 1 if self.null_block is not None else 0
        assert len(self._free) + n_meta + n_null <= self.cap

    # -- id plumbing ----------------------------------------------------------
    def _evict_one(self) -> int:
        for key, bid in self._cached.items():      # head = LRU
            if self._meta[bid].refcount == 0:
                del self._cached[key]
                del self._meta[bid]
                self.stats.evictions += 1
                if self.evict_callback is not None:
                    self.evict_callback(key)
                return bid
        self.stats.exhausted += 1
        raise KVPoolExhausted(
            f"all {self.cap} blocks pinned; cannot allocate")

    def _take(self) -> int:
        bid = self._free.pop() if self._free else self._evict_one()
        self.stats.allocs += 1
        return bid

    def _reclaim(self, bid: int) -> None:
        m = self._meta[bid]
        if m.refcount == 0 and m.key is None:
            del self._meta[bid]
            self._free.append(bid)

    # -- engine-side API (block ids + pinning) --------------------------------
    def alloc(self, owner, n: int) -> list:
        """Pin ``n`` fresh blocks to ``owner``; evicts LRU cached blocks as
        needed. Raises ``KVPoolExhausted`` (allocating nothing) if the pool
        cannot cover the request."""
        evictable = self.n_free + self.n_cached
        if n > evictable:
            self.stats.exhausted += 1
            raise KVPoolExhausted(
                f"need {n} blocks, only {evictable} free+evictable of "
                f"{self.cap}")
        ids = []
        for _ in range(n):
            bid = self._take()
            self._meta[bid] = _BlockMeta(refcount=1)
            ids.append(bid)
        self._owned.setdefault(owner, []).extend(ids)
        return ids

    def lookup(self, prefix_id, n_blocks: int) -> list:
        """Longest resident *run* ``(prefix_id, 0..k-1)``, ``k <=
        n_blocks``; touches LRU recency. Returns block ids (not pinned)."""
        ids = []
        for j in range(n_blocks):
            key = (prefix_id, j)
            bid = self._cached.get(key)
            if bid is None:
                break
            self._cached.move_to_end(key)
            ids.append(bid)
        return ids

    def share(self, owner, prefix_id, n_blocks: int) -> list:
        """Pin the longest resident prefix run for ``owner`` (copy-free
        sharing). Returns the shared block ids, possibly empty."""
        ids = self.lookup(prefix_id, n_blocks)
        for bid in ids:
            self._meta[bid].refcount += 1
        self._owned.setdefault(owner, []).extend(ids)
        self.stats.shared_hits += len(ids)
        return ids

    def release(self, owner, prefix_id=None, keep_blocks: int = 0) -> None:
        """Unpin everything ``owner`` holds. The first ``keep_blocks``
        blocks (the full prompt-prefix blocks, in table order) are retained
        in the prefix cache under ``(prefix_id, j)``; the rest are freed
        once their refcount drops to zero."""
        ids = self._owned.pop(owner, [])
        for j, bid in enumerate(ids):
            m = self._meta[bid]
            m.refcount -= 1
            if prefix_id is not None and j < keep_blocks:
                key = (prefix_id, j)
                prev = self._cached.get(key)
                if prev is None or prev == bid:
                    if m.key is None:
                        m.key = key
                    self._cached[key] = bid
                    self._cached.move_to_end(key)
                # else: another request already cached this prefix block;
                # ours is a duplicate and falls through to reclaim.
            self._reclaim(bid)

    def table_of(self, owner) -> list:
        return list(self._owned.get(owner, ()))

    # -- sim-side API (occupancy only; subsumes PrefixCachePool) --------------
    def hit_fraction(self, prefix_id, n_blocks: int) -> float:
        """Fraction of ``(prefix_id, 0..n_blocks-1)`` resident (touches
        recency per hit) — the old ``PrefixCachePool`` probe."""
        if n_blocks == 0:
            return 0.0
        hits = 0
        for j in range(n_blocks):
            key = (prefix_id, j)
            if key in self._cached:
                hits += 1
                self._cached.move_to_end(key)
        return hits / n_blocks

    def insert(self, prefix_id, n_blocks: int) -> None:
        """Mark ``(prefix_id, 0..n_blocks-1)`` resident (MRU), allocating
        backing ids and evicting LRU unpinned blocks as needed."""
        for j in range(n_blocks):
            key = (prefix_id, j)
            bid = self._cached.get(key)
            if bid is not None:
                self._cached.move_to_end(key)
                continue
            bid = self._take()
            self._meta[bid] = _BlockMeta(refcount=0, key=key)
            self._cached[key] = bid

    def touch_decode(self, rid, blocks: int) -> None:
        """Decode working set churns the pool (residency decay, App. C):
        keyed on a per-request pseudo-prefix so it competes in the LRU."""
        self.insert(("decode", rid), blocks)


# Backwards-compatible name: the old dense prefix pool is now a view of the
# paged pool (same LRU, same probe semantics).
PrefixCachePool = PagedKVPool
