"""Fleet tier: a multi-replica serving gateway with prefix-aware
routing (SERVING.md §8).

One ``FleetGateway`` owns N *replicas* — each a full serving stack
(``ServeCore`` + its own ``PagedKVPool``, exactly the per-process
objects the single-node tiers use) — plus one global
``RadixPrefixTree`` (serve/prefix_tree.py) indexing which replica holds
which prompt prefix resident. Requests flow::

    trace -> router queue -> (select replica) -> replica core -> slots

The *router* decides two things, and the fleet policies differ only
there, so comparisons isolate routing (every replica runs FIFO
admission internally):

* **dispatch discipline** — the order the router's own backlog drains
  in. FIFO for most policies; the ``reciprocating`` router drains a
  detached entry segment LIFO-within / FIFO-across with the paper's
  bounded bypass (``core/admission.py::ReciprocatingQueue``) — the
  arrival-stack discipline lifted from a lock doorway to a fleet
  doorway, serving burst members while their tenant prefix is hottest.
* **target selection** — which slack-bearing replica gets the request:
  ``round_robin`` / ``random`` / ``least_loaded`` baselines, or
  ``prefix`` (and ``reciprocating``): the replica advertising the
  longest live cached prefix in the global tree, falling back to
  least-loaded on a cold prefix.

Coherence: each replica pool is constructed with an ``evict_callback``
that withdraws the replica from the tree when LRU eviction drops a
prefix block, so the tree never advertises stale residency for longer
than the eviction that killed it (regression-tested in
tests/test_gateway.py). Pool decode-churn keys are not tree-addressed
and fall through the callback harmlessly.

Memory discipline: traces stream in arrival order, token arrays are
dropped at dispatch (the interned tree chain replaces them), finished
requests are folded into streaming ``FleetStats`` every step — a
million-request trace runs in O(in-flight) memory.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.admission import ReciprocatingQueue
from repro.serve.core import DrainStalled, Executor, ServeCore
from repro.serve.kv_cache import PagedKVPool
from repro.serve.prefix_tree import RadixPrefixTree
from repro.serve.traces import TraceRequest


# -- per-replica work model ----------------------------------------------------

class FleetExecutor(Executor):
    """Cost-model executor over tree-addressed prompt blocks: prefill
    pays one chunk per missed block, decode is 1 token/step + pool
    churn — the same shape as ``scheduler.SimExecutor`` but with the
    prefix cache keyed by global tree node ids instead of per-family
    ``prefix_id``s, so hits reflect exactly what the router indexed."""

    def __init__(self, pool: PagedKVPool, block_tokens: int,
                 prefill_cost_per_block: float):
        self.pool = pool
        self.bt = block_tokens
        self.pc = prefill_cost_per_block
        self.hit_blocks = 0
        self.total_blocks = 0

    def admit(self, r: TraceRequest, now: float) -> None:
        chain = r.chain or []
        hits = 0
        for nid in chain:               # longest resident run from root
            if self.pool.hit_fraction(nid, 1) < 1.0:
                break
            hits += 1
        total = max(1, math.ceil(r.prompt_tokens / self.bt))
        r.prefill_hit = hits / total
        self.hit_blocks += hits
        self.total_blocks += total
        r._prefill_left = (total - hits) * self.pc
        r._decode_left = r.decode_tokens
        for nid in chain:               # prefill (re)materializes the chain
            self.pool.insert(nid, 1)

    def work(self, active: list, now: float) -> list:
        done = []
        for r in active:
            if r._prefill_left > 0:
                r._prefill_left -= 1.0
                continue
            if r.first_token < 0:
                r.first_token = now
            r._decode_left -= 1
            self.pool.touch_decode(r.rid, 1)
            if r._decode_left <= 0:
                done.append(r)
        return done


class Replica:
    """One engine replica: a core + pool pair, tree-coherent."""

    def __init__(self, idx: int, tree: RadixPrefixTree, max_slots: int,
                 pool_blocks: int, prefill_cost_per_block: float,
                 seed: int = 0):
        self.idx = idx
        self.pool = PagedKVPool(
            pool_blocks,
            evict_callback=lambda key: tree.evict(key[0], idx))
        self.executor = FleetExecutor(self.pool, tree.block,
                                      prefill_cost_per_block)
        self.core = ServeCore(self.executor, policy="fifo",
                              max_slots=max_slots, seed=seed + idx)
        self.dispatched = 0


# -- routing policies ----------------------------------------------------------

class Router:
    """Base router: FIFO dispatch + subclass-chosen target selection.
    ``select`` only ever sees replicas with dispatch-window slack; it
    returns one of them (never None)."""
    name = "base"

    def __init__(self, gateway: "FleetGateway", seed: int = 0):
        self.gw = gateway
        self.rng = np.random.default_rng(seed)
        self._q: deque = deque()
        self._head: TraceRequest | None = None  # popped, awaiting slack

    def submit(self, req: TraceRequest) -> None:
        self._q.append(req)

    def _pop(self):
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q) + (1 if self._head is not None else 0)

    def select(self, req: TraceRequest, candidates: list) -> int:
        raise NotImplementedError

    def dispatch(self, now: float) -> None:
        """Drain the router backlog into replicas until it empties or
        every replica's dispatch window is full (backpressure). A
        popped-but-unplaceable request parks in ``_head`` so bounded
        disciplines never see a re-push."""
        while True:
            req = self._head if self._head is not None else self._pop()
            if req is None:
                return
            candidates = self.gw.slack_replicas()
            if not candidates:
                self._head = req
                return
            self._head = None
            self.gw.place(req, self.select(req, candidates))


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self, gateway, seed=0):
        super().__init__(gateway, seed)
        self._next = 0

    def select(self, req, candidates):
        n = len(self.gw.replicas)
        for _ in range(n):
            idx = self._next % n
            self._next += 1
            if idx in candidates:
                return idx
        return candidates[0]


class RandomRouter(Router):
    name = "random"

    def select(self, req, candidates):
        return int(self.rng.choice(candidates))


class LeastLoadedRouter(Router):
    name = "least_loaded"

    def select(self, req, candidates):
        return min(candidates, key=lambda i: self.gw.replicas[i].core.backlog)


class PrefixRouter(Router):
    """Cache-aware load balancing: score each candidate by the prefill
    steps its cached prefix saves minus a load penalty per queued
    request, and take the max. Pure affinity would pile a tenant's
    whole burst on one replica while the rest idle; the load term makes
    the burst overflow to the next-least-loaded replica, and the
    dispatch-time ``insert`` then advertises the tenant there too — hot
    prefixes replicate exactly as wide as their traffic warrants. With
    no cached prefix anywhere this degenerates to least-loaded."""
    name = "prefix"

    def select(self, req, candidates):
        depths = self.gw.tree.match(req.tokens)

        def score(i):
            saved = self.gw.pc * depths.get(i, 0)
            return saved - self.gw.load_penalty * self.gw.replicas[i].core.backlog

        return max(candidates, key=score)


class ReciprocatingRouter(PrefixRouter):
    """Prefix-aware targets + the paper's arrival-stack dispatch: the
    router backlog is a ``ReciprocatingQueue``, so a burst detaches as
    one entry segment and drains newest-first with bypass bounded at
    one segment — burst members land while their shared tenant prefix
    is still resident, without LIFO starvation."""
    name = "reciprocating"

    def __init__(self, gateway, seed=0):
        super().__init__(gateway, seed)
        self._rq = ReciprocatingQueue(seed)

    def submit(self, req):
        self._rq.push(req)

    def _pop(self):
        return self._rq.pop()

    def __len__(self):
        return len(self._rq) + (1 if self._head is not None else 0)


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "random": RandomRouter,
    "least_loaded": LeastLoadedRouter,
    "prefix": PrefixRouter,
    "reciprocating": ReciprocatingRouter,
}


def catalogue() -> list:
    """(name, one-line description) rows for ``repro.bench list
    --routers``."""
    return [
        ("round_robin", "cycle replicas in order; ignores cache and load"),
        ("random", "uniform random replica; the cache-shredding baseline"),
        ("least_loaded", "smallest backlog; balances load, ignores cache"),
        ("prefix", "longest live cached prefix in the global radix tree; "
                   "least-loaded fallback when cold"),
        ("reciprocating", "prefix targets + arrival-stack dispatch "
                          "(entry segments, bounded bypass) at the fleet "
                          "doorway"),
    ]


# -- fleet-level accounting ----------------------------------------------------

@dataclass
class FleetStats:
    """Streaming fleet metrics: O(1) per finished request, O(bins) for
    the TTFT tail (integer-step histogram — exact, since time is
    integral)."""
    n: int = 0
    sum_ttft: float = 0.0
    sum_tpot: float = 0.0
    sum_wait: float = 0.0
    gen_tokens: int = 0
    max_ttft: float = 0.0
    ttft_hist: dict = field(default_factory=dict)
    per_replica: list = field(default_factory=list)

    def observe(self, r: TraceRequest) -> None:
        self.n += 1
        ttft = r.first_token - r.arrival
        self.sum_ttft += ttft
        self.max_ttft = max(self.max_ttft, ttft)
        b = int(ttft)
        self.ttft_hist[b] = self.ttft_hist.get(b, 0) + 1
        self.sum_tpot += ((r.finished - r.first_token)
                          / max(r.decode_tokens - 1, 1))
        self.sum_wait += r.admitted - r.arrival
        self.gen_tokens += r.decode_tokens

    def p_ttft(self, q: float) -> float:
        rank = q * self.n
        seen = 0
        for b in sorted(self.ttft_hist):
            seen += self.ttft_hist[b]
            if seen >= rank:
                return float(b)
        return self.max_ttft

    def summary(self, elapsed: float, hit_blocks: int,
                total_blocks: int) -> dict:
        n = max(self.n, 1)
        counts = self.per_replica or [0]
        mean_load = sum(counts) / len(counts)
        return {
            "n": self.n,
            "mean_ttft": self.sum_ttft / n,
            "p99_ttft": self.p_ttft(0.99),
            "max_ttft": self.max_ttft,
            "mean_tpot": self.sum_tpot / n,
            "mean_wait": self.sum_wait / n,
            "goodput_tok_per_step": self.gen_tokens / max(elapsed, 1e-9),
            "hit_rate": hit_blocks / max(total_blocks, 1),
            "load_imbalance": max(counts) / max(mean_load, 1e-9),
        }


# -- the gateway ---------------------------------------------------------------

class FleetGateway:
    """N replicas behind one router, stepped in lockstep (1 gateway
    step == 1 decode iteration on every replica)."""

    def __init__(self, n_replicas: int = 4, router: str = "prefix",
                 max_slots: int = 8, pool_blocks: int = 256,
                 block_tokens: int = 16, prefill_cost_per_block: float = 1.0,
                 queue_depth: int = 4, load_penalty: float = 4.0,
                 seed: int = 0):
        self.tree = RadixPrefixTree(block_tokens)
        self.pc = prefill_cost_per_block
        # marginal TTFT cost of one queued request ahead of you,
        # ~ mean service time / slots; the prefix router's exchange rate
        # between cache affinity and queueing delay
        self.load_penalty = load_penalty
        self.replicas = [
            Replica(i, self.tree, max_slots, pool_blocks,
                    prefill_cost_per_block, seed=seed)
            for i in range(n_replicas)
        ]
        if router not in ROUTERS:
            raise ValueError(f"unknown router {router!r}; "
                             f"one of {sorted(ROUTERS)}")
        self.router = ROUTERS[router](self, seed)
        self.router_name = router
        self.window = max_slots * queue_depth   # dispatch window / replica
        self.stats = FleetStats(per_replica=[0] * n_replicas)
        self.time = 0.0

    # -- router-facing surface ------------------------------------------------
    def slack_replicas(self) -> list:
        """Replicas whose dispatch window isn't full. The window
        (slots x queue_depth) is the backpressure knob: small enough
        that the router keeps choices, large enough to hide dispatch
        latency."""
        return [r.idx for r in self.replicas
                if r.core.backlog < self.window]

    def place(self, req: TraceRequest, idx: int) -> None:
        """Commit a routing decision: advertise the prompt chain in the
        tree, drop the token array (the chain now addresses it), hand
        the request to the replica core."""
        rep = self.replicas[idx]
        req.replica = idx
        req.chain = self.tree.insert(req.tokens, idx)
        req.tokens = None
        rep.dispatched += 1
        self.stats.per_replica[idx] += 1
        rep.core.submit(req)

    # -- drive ----------------------------------------------------------------
    def step(self) -> None:
        self.time += 1.0
        self.router.dispatch(self.time)
        for rep in self.replicas:
            rep.core.step()
            fin = rep.core.stats.finished
            for r in fin:
                self.stats.observe(r)
            fin.clear()                 # streaming: never accumulate

    def has_work(self) -> bool:
        return bool(len(self.router)
                    or any(r.core.has_work() for r in self.replicas))

    def run(self, trace, max_steps: int = 50_000_000) -> dict:
        """Drive a trace (any iterator of ``TraceRequest`` in arrival
        order) to completion and return the fleet summary."""
        it = iter(trace)
        nxt = next(it, None)
        steps = 0
        while nxt is not None or self.has_work():
            if steps >= max_steps:
                raise DrainStalled(
                    f"fleet drain({max_steps=}) exhausted with "
                    f"{len(self.router)} routed-queue, "
                    f"{sum(r.core.backlog for r in self.replicas)} "
                    f"in-replica requests")
            while nxt is not None and nxt.arrival <= self.time + 1.0:
                self.router.submit(nxt)
                nxt = next(it, None)
            self.step()
            steps += 1
        return self.summary()

    def summary(self) -> dict:
        hit = sum(r.executor.hit_blocks for r in self.replicas)
        tot = sum(r.executor.total_blocks for r in self.replicas)
        out = self.stats.summary(self.time, hit, tot)
        out["router"] = self.router_name
        out["tree_nodes"] = self.tree.n_nodes
        out["bookkeeping_ops"] = sum(r.core.bookkeeping_ops
                                     for r in self.replicas)
        return out
