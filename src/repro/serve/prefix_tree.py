"""Global radix prefix tree — the fleet router's cache index
(SERVING.md §8).

The gateway routes each request to the replica already holding its
longest *live* cached prefix. This tree is the index that makes that
O(prompt blocks): one node per full token block, edges labelled by the
block's token bytes, each node carrying the set of replicas that
(claim to) hold that block resident. Two request streams that share a
system prompt share a path; their unique suffixes branch.

The tree plays three roles at once:

* **content addressing** — every node has a stable integer id that
  uniquely identifies the *chain* root..node (parent identity is part of
  the interning key, so equal block content under different prefixes
  gets different ids). Per-replica ``PagedKVPool``s key their cached
  blocks on these ids (``chain_ids``), which is what lets one global
  index describe N independent pools without hashing collisions.
* **routing index** — ``match(tokens)`` walks the tree once and returns,
  for every replica, the length of the longest prefix run it is
  advertised for. A run must be *contiguous from the root*: a replica
  that evicted block 2 cannot serve blocks 3.. even if they linger in
  its pool, so it drops out of the walk at depth 2.
* **coherence mirror** — each replica pool's ``evict_callback``
  (serve/kv_cache.py) calls ``evict(node_id, replica)`` when LRU
  eviction drops a block, which removes the replica from that node AND
  its whole subtree (a deeper block is unreachable without its prefix).
  Nodes left with no replicas and no children are pruned, so tree size
  tracks fleet-wide residency, not trace length.

The tree never stores token arrays — edges are the raw little-endian
int32 bytes of one block (cheap to slice out of a prompt, hashable,
exact). Partial trailing blocks are never indexed, mirroring the
engine-side rule that only full prompt-prefix blocks are shareable
(serve/engine.py ``_prefix_blocks``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


class _Node:
    __slots__ = ("nid", "parent", "edge", "children", "replicas")

    def __init__(self, nid: int, parent: "_Node | None", edge: bytes):
        self.nid = nid
        self.parent = parent
        self.edge = edge                # block bytes labelling parent->self
        self.children: dict = {}        # block bytes -> _Node
        self.replicas: set = set()      # replica indices advertised here


@dataclass
class TreeStats:
    interned: int = 0               # nodes ever created
    pruned: int = 0                 # nodes reclaimed after eviction
    evictions: int = 0              # evict() calls that removed a replica
    matches: int = 0                # match() walks

    def to_dict(self) -> dict:
        return {"interned": self.interned, "pruned": self.pruned,
                "evictions": self.evictions, "matches": self.matches}


class RadixPrefixTree:
    """Block-granular radix tree over token prefixes (SERVING.md §8)."""

    def __init__(self, block_tokens: int):
        if block_tokens < 1:
            raise ValueError("block_tokens must be >= 1")
        self.block = block_tokens
        self._root = _Node(0, None, b"")
        self._by_id: dict = {0: self._root}     # nid -> node (evict path)
        self._next_id = 1
        self.stats = TreeStats()

    # -- block plumbing -------------------------------------------------------
    def blocks_of(self, tokens) -> list:
        """Full-block byte labels of ``tokens`` (partial tail dropped)."""
        arr = np.asarray(tokens, np.int32).reshape(-1)
        bs = self.block
        return [arr[j * bs:(j + 1) * bs].tobytes()
                for j in range(len(arr) // bs)]

    @property
    def n_nodes(self) -> int:
        """Live nodes, excluding the root."""
        return len(self._by_id) - 1

    # -- interning + advertisement --------------------------------------------
    def _descend(self, blk: bytes, node: _Node) -> _Node:
        child = node.children.get(blk)
        if child is None:
            child = _Node(self._next_id, node, blk)
            node.children[blk] = child
            self._by_id[child.nid] = child
            self._next_id += 1
            self.stats.interned += 1
        return child

    def chain_ids(self, tokens) -> list:
        """Intern the full-block chain of ``tokens`` and return one
        stable node id per block — the content addresses a replica pool
        keys its cached blocks under. Does NOT advertise a replica."""
        node = self._root
        ids = []
        for blk in self.blocks_of(tokens):
            node = self._descend(blk, node)
            ids.append(node.nid)
        return ids

    def insert(self, tokens, replica: int) -> list:
        """Advertise ``replica`` along the full-block chain of
        ``tokens`` (the router calls this at dispatch: the blocks will
        be resident once the replica prefills). Returns the chain's node
        ids, same as ``chain_ids``."""
        node = self._root
        ids = []
        for blk in self.blocks_of(tokens):
            node = self._descend(blk, node)
            node.replicas.add(replica)
            ids.append(node.nid)
        return ids

    # -- routing --------------------------------------------------------------
    def match(self, tokens) -> dict:
        """Longest advertised prefix run per replica: ``{replica: depth
        in blocks}`` for every replica advertised on a contiguous run
        from the root. Replicas absent from the dict match 0 blocks."""
        self.stats.matches += 1
        out: dict = {}
        node = self._root
        live: set | None = None
        depth = 0
        for blk in self.blocks_of(tokens):
            node = node.children.get(blk)
            if node is None:
                break
            live = (set(node.replicas) if live is None
                    else live & node.replicas)
            if not live:
                break
            depth += 1
            for r in live:
                out[r] = depth
        return out

    # -- eviction coherence ---------------------------------------------------
    def evict(self, node_id: int, replica: int) -> bool:
        """A replica's pool dropped the block content-addressed by
        ``node_id``: withdraw the replica from that node and its whole
        subtree (deeper blocks are unreachable without their prefix),
        pruning nodes left with no replicas and no children. Unknown ids
        (e.g. a pool's decode-churn keys) are ignored. Returns whether a
        withdrawal happened."""
        node = self._by_id.get(node_id)
        if node is None:
            return False
        hit = False
        visited = []
        stack = [node]
        while stack:
            n = stack.pop()
            visited.append(n)
            if replica in n.replicas:
                n.replicas.discard(replica)
                hit = True
            stack.extend(n.children.values())
        if hit:
            self.stats.evictions += 1
        # prune every node the withdrawal may have emptied; _prune_up
        # re-checks emptiness on each upward hop, so visit order is
        # irrelevant and already-pruned nodes (parent=None) are no-ops
        for n in visited:
            self._prune_up(n)
        return hit

    def drop_replica(self, replica: int) -> None:
        """Withdraw ``replica`` everywhere (replica drained/restarted)."""
        visited = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            visited.append(n)
            n.replicas.discard(replica)
            stack.extend(n.children.values())
        for n in visited:
            self._prune_up(n)

    def _prune_up(self, node: _Node) -> None:
        while (node.parent is not None and not node.replicas
               and not node.children):
            parent = node.parent
            del parent.children[node.edge]
            del self._by_id[node.nid]
            node.parent = None
            self.stats.pruned += 1
            node = parent

    # -- invariants (exercised by tests/test_gateway.py) ----------------------
    def check(self) -> None:
        seen = {}
        stack = [self._root]
        while stack:
            n = stack.pop()
            seen[n.nid] = n
            for edge, child in n.children.items():
                assert child.parent is n, f"broken parent link at {child.nid}"
                assert child.edge == edge, f"edge mismatch at {child.nid}"
                assert (child.replicas or child.children), \
                    f"unpruned empty leaf {child.nid}"
                stack.append(child)
        assert seen == self._by_id, "id index out of sync with tree"
