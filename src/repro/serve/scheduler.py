"""Continuous-batching scheduler with reciprocating admission.

The engine admits waiting requests into free decode slots according to an
``AdmissionQueue`` policy. The paper's reciprocating discipline gives:

* O(1) admission path (arrival stack push / segment pop — no heap),
* bounded bypass => no request starvation (unlike raw LIFO),
* LIFO-within-segment => a just-arrived request is served while its prompt
  prefix is still resident in the KV/prefix block pool — the App. C decay
  argument with the pool as the "LLC".

``PrefixCachePool`` models the pool: fixed capacity of blocks, LRU
eviction; a request's prefill cost is discounted by the fraction of its
prefix blocks still resident (shared-prefix workloads => residency decays
as other requests churn the pool — exponential in load, exactly App. C).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

from repro.core.admission import POLICIES, AdmissionQueue


@dataclass
class Request:
    rid: int
    arrival: float
    prefix_id: int              # shared-prompt family (prefix cache key)
    prefix_blocks: int          # blocks covered by the shared prefix
    prompt_blocks: int          # unique prompt blocks
    decode_tokens: int
    # runtime
    admitted: float = -1.0
    finished: float = -1.0
    prefill_hit: float = 0.0


class PrefixCachePool:
    """LRU pool of KV blocks keyed by (prefix_id, block_idx)."""

    def __init__(self, capacity_blocks: int):
        self.cap = capacity_blocks
        self._lru: OrderedDict = OrderedDict()

    def hit_fraction(self, prefix_id: int, n_blocks: int) -> float:
        if n_blocks == 0:
            return 0.0
        hits = 0
        for b in range(n_blocks):
            k = (prefix_id, b)
            if k in self._lru:
                hits += 1
                self._lru.move_to_end(k)
        return hits / n_blocks

    def insert(self, prefix_id: int, n_blocks: int) -> None:
        for b in range(n_blocks):
            k = (prefix_id, b)
            self._lru[k] = True
            self._lru.move_to_end(k)
        while len(self._lru) > self.cap:
            self._lru.popitem(last=False)

    def touch_decode(self, rid: int, blocks: int) -> None:
        """Decode working set churns the pool (the residency decay)."""
        self.insert(-rid - 1, blocks)


@dataclass
class SchedulerStats:
    finished: list = field(default_factory=list)

    def summary(self) -> dict:
        if not self.finished:
            return {}
        waits = sorted(r.admitted - r.arrival for r in self.finished)
        hits = [r.prefill_hit for r in self.finished]
        lat = sorted(r.finished - r.arrival for r in self.finished)
        n = len(waits)
        per_prefix: dict = {}
        for r in self.finished:
            per_prefix.setdefault(r.prefix_id, []).append(r)
        return {
            "n": n,
            "mean_wait": sum(waits) / n,
            "p50_wait": waits[n // 2],
            "p99_wait": waits[min(n - 1, int(n * 0.99))],
            "max_wait": waits[-1],
            "p99_latency": lat[min(n - 1, int(n * 0.99))],
            "prefix_hit_rate": sum(hits) / n,
            "throughput_rps": n / max(max(r.finished for r in self.finished),
                                      1e-9),
        }


class ContinuousBatcher:
    """Discrete-time serving simulation (1 step = 1 decode iteration).

    Prefill cost (steps) = blocks * (1 - hit_fraction) * prefill_step_cost;
    each active request decodes 1 token/step; slots = max_batch.
    """

    def __init__(self, policy: str = "reciprocating", max_batch: int = 8,
                 pool_blocks: int = 512, prefill_cost_per_block: float = 0.25,
                 seed: int = 0):
        self.queue: AdmissionQueue = POLICIES[policy](seed)
        self.policy = policy
        self.max_batch = max_batch
        self.pool = PrefixCachePool(pool_blocks)
        self.pc = prefill_cost_per_block
        self.active: list = []
        self.pending: list = []         # submitted, not yet arrived
        self.stats = SchedulerStats()
        self.time = 0.0

    def submit(self, req: Request) -> None:
        self.pending.append(req)        # becomes visible at req.arrival

    def step(self) -> None:
        self.time += 1.0
        # arrivals become visible (O(1) doorway: arrival-stack push).
        # Multi-turn model: a follow-up request's prefix blocks are warm AT
        # ARRIVAL (its previous turn just decoded them) and decay under pool
        # churn while it waits — the paper's residency-decay structure.
        still = []
        for r in self.pending:
            if r.arrival <= self.time:
                self.pool.insert(r.prefix_id, r.prefix_blocks)
                self.queue.push(r)
            else:
                still.append(r)
        self.pending = still
        # admit into free slots
        while len(self.active) < self.max_batch:
            r = self.queue.pop()
            if r is None:
                break
            r.admitted = self.time
            hit = self.pool.hit_fraction(r.prefix_id, r.prefix_blocks)
            r.prefill_hit = hit
            miss_blocks = (r.prefix_blocks * (1 - hit)) + r.prompt_blocks
            r._prefill_left = miss_blocks * self.pc
            r._decode_left = r.decode_tokens
            self.pool.insert(r.prefix_id, r.prefix_blocks)
            self.active.append(r)
        # run
        done = []
        for r in self.active:
            if r._prefill_left > 0:
                r._prefill_left -= 1.0
                continue
            r._decode_left -= 1
            self.pool.touch_decode(r.rid, 1)
            if r._decode_left <= 0:
                r.finished = self.time
                done.append(r)
        for r in done:
            self.active.remove(r)
            self.stats.finished.append(r)

    def drain(self, max_steps: int = 1_000_000) -> None:
        steps = 0
        while (self.active or len(self.queue) or self.pending) \
                and steps < max_steps:
            self.step()
            steps += 1
