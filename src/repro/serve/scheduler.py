"""Discrete-time serving simulator — a cost-model frontend over the
shared continuous-batching core (SERVING.md §1).

The scheduling logic (per-step admission into freed slots, policy queue,
early exit) lives in ``serve/core.py`` and is the same code the
model-backed engine runs; this module supplies only the *cost model*:

* prefill cost (steps) = missed blocks × ``prefill_cost_per_block``,
  where the miss fraction is probed against the paged KV pool
  (``serve/kv_cache.py``, SERVING.md §2) — the App. C decay argument with
  the pool as the "LLC";
* each active request decodes 1 token/step and churns the pool.

Multi-turn model: a follow-up request's prefix blocks are warm AT ARRIVAL
(its previous turn just decoded them) and decay under pool churn while it
waits — the paper's residency-decay structure. The reciprocating
discipline admits just-arrived requests while their prefix is still
resident, without raw LIFO's starvation pathology (SERVING.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.serve.core import Executor, ServeCore, ServeStats
from repro.serve.kv_cache import PagedKVPool, PrefixCachePool  # noqa: F401

# Re-exported for callers that predate serve/core.py.
SchedulerStats = ServeStats


@dataclass(eq=False)              # identity semantics: the core keys its
class Request:                    # slot dict on id(req), so two requests
                                  # with equal fields never collide
    rid: int
    arrival: float
    prefix_id: int              # shared-prompt family (prefix cache key)
    prefix_blocks: int          # blocks covered by the shared prefix
    prompt_blocks: int          # unique prompt blocks
    decode_tokens: int
    # runtime (set by the core / executor)
    admitted: float = -1.0
    finished: float = -1.0
    prefill_hit: float = 0.0
    # work state (SimExecutor): declared fields, not step()-injected attrs
    _prefill_left: float = 0.0
    _decode_left: int = 0


class SimExecutor(Executor):
    """Cost-model executor: blocks and steps instead of arrays and
    tokens (SERVING.md §5 fidelity contract)."""

    def __init__(self, pool: PagedKVPool, prefill_cost_per_block: float):
        self.pool = pool
        self.pc = prefill_cost_per_block

    def on_arrival(self, r: Request, now: float) -> None:
        # the previous turn's decode just wrote these blocks: warm at
        # arrival, decaying under churn while the request waits.
        self.pool.insert(r.prefix_id, r.prefix_blocks)

    def admit(self, r: Request, now: float) -> None:
        hit = self.pool.hit_fraction(r.prefix_id, r.prefix_blocks)
        r.prefill_hit = hit
        miss_blocks = (r.prefix_blocks * (1 - hit)) + r.prompt_blocks
        r._prefill_left = miss_blocks * self.pc
        r._decode_left = r.decode_tokens
        self.pool.insert(r.prefix_id, r.prefix_blocks)

    def work(self, active: list, now: float) -> list:
        done = []
        for r in active:
            if r._prefill_left > 0:     # chunked prefill: one chunk/step
                r._prefill_left -= 1.0
                continue
            r._decode_left -= 1
            self.pool.touch_decode(r.rid, 1)
            if r._decode_left <= 0:
                done.append(r)
        return done


class ContinuousBatcher:
    """Discrete-time serving simulation (1 step = 1 decode iteration)
    over the shared ``ServeCore`` — the sim frontend of SERVING.md §1."""

    def __init__(self, policy: str = "reciprocating", max_batch: int = 8,
                 pool_blocks: int = 512, prefill_cost_per_block: float = 0.25,
                 seed: int = 0):
        self.pool = PagedKVPool(pool_blocks)
        self.core = ServeCore(SimExecutor(self.pool, prefill_cost_per_block),
                              policy=policy, max_slots=max_batch, seed=seed)
        self.policy = policy
        self.max_batch = max_batch
        self.pc = prefill_cost_per_block

    # thin frontend: expose the core's state under the historical names
    @property
    def queue(self):
        return self.core.queue

    @property
    def active(self) -> list:
        return self.core.active

    @property
    def pending(self) -> list:
        return self.core.pending

    @property
    def stats(self) -> ServeStats:
        return self.core.stats

    @property
    def time(self) -> float:
        return self.core.time

    def submit(self, req: Request) -> None:
        self.core.submit(req)           # becomes visible at req.arrival

    def step(self) -> None:
        self.core.step()

    def drain(self, max_steps: int = 1_000_000) -> None:
        self.core.drain(max_steps)      # raises DrainStalled on exhaustion
