"""Model-backed inference engine (runnable end-to-end on CPU smoke configs;
the same ``prefill_step`` / ``decode_step`` are what the dry-run lowers at
production scale).

Serving proceeds in *segments* — the engine literally runs the paper's
discipline: requests push onto the arrival stack; when the current batch
(entry segment) drains, the stack is detached wholesale and becomes the
next batch, served LIFO-within / FIFO-across. Bounded bypass guarantees no
request starves; fresh arrivals ride their still-warm prefix state.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.admission import POLICIES
from repro.models import decode as D_
from repro.sharding.ctx import MeshCtx, trivial_ctx


@dataclass
class GenRequest:
    rid: int
    tokens: np.ndarray            # prompt (1-D int32)
    max_new: int = 16
    out: list = field(default_factory=list)


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, ctx: MeshCtx | None = None,
                 policy: str = "reciprocating", max_batch: int = 4,
                 max_seq: int = 128):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or trivial_ctx()
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.queue = POLICIES[policy]()
        self._prefill = jax.jit(
            lambda p, b: D_.prefill_step(p, b, cfg, self.ctx))
        self._decode = jax.jit(
            lambda p, c, t: D_.decode_step(p, c, t, cfg, self.ctx))

    def submit(self, req: GenRequest) -> None:
        self.queue.push(req)

    def _make_batch(self, reqs: list[GenRequest]):
        B = len(reqs)
        L = max(len(r.tokens) for r in reqs)
        toks = np.zeros((B, L), np.int32)
        for i, r in enumerate(reqs):
            toks[i, L - len(r.tokens):] = r.tokens      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.n_patches:
            batch["patches"] = jnp.zeros(
                (B, self.cfg.n_patches, self.cfg.d_model), self.cfg.dtype)
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (B, self.cfg.enc_frames, self.cfg.d_model), self.cfg.dtype)
        return batch

    def run(self) -> list[GenRequest]:
        """Serve everything queued; returns finished requests in completion
        order."""
        finished: list[GenRequest] = []
        while len(self.queue):
            segment = []                 # detach up to max_batch as a batch
            while len(segment) < self.max_batch:
                r = self.queue.pop()
                if r is None:
                    break
                segment.append(r)
            logits, cache = self._prefill(self.params, self._make_batch(segment))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            steps = max(r.max_new for r in segment)
            for _ in range(steps):
                for i, r in enumerate(segment):
                    if len(r.out) < r.max_new:
                        r.out.append(int(tok[i]))
                logits, cache = self._decode(self.params, cache, tok)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            finished.extend(segment)
        return finished
