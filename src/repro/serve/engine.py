"""Model-backed continuous-batching inference engine (SERVING.md §1-§4).

The engine is the model frontend over the shared scheduler core
(`serve/core.py`): the same per-step admission loop the discrete-time
simulator runs, with an executor that computes real tokens. One core
``step()`` is one batched ``decode_step`` for every occupied slot:

* **per-step admission** — a freed slot is refilled from the
  ``AdmissionQueue`` on the next step, not when the whole batch drains;
* **per-request early exit** — a request leaves its slot the step its
  ``max_new`` tokens are done; finished slots never burn decode compute;
* **chunked prefill interleaved with decode** — the first
  ``prefill_chunk`` prompt tokens go through ``prefill_step``; any
  remainder is fed through the decode path one token per step alongside
  the other slots' decode (SERVING.md §4);
* **paged KV** — on the supported families the cache is a block pool
  indexed by per-slot block tables (``serve/kv_cache.py``), decoded with
  ``models/decode.py::paged_decode_step`` and with copy-free sharing of
  full prompt-prefix blocks between requests that declare the same
  ``prefix_id`` (SERVING.md §3). Other families use a dense per-slot
  cache with the identical scheduling behaviour.

Both executors compute each batch row independently of its neighbours,
so the admission policy changes completion *order* only, never token
values (property-tested in tests/test_system.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode as D_
from repro.serve.core import Executor, ServeCore, ServeStats  # noqa: F401
from repro.serve.kv_cache import PagedKVPool
from repro.sharding.ctx import MeshCtx, trivial_ctx


@dataclass(eq=False)              # identity semantics: the core keys its
class GenRequest:                 # slot dict on id(req), so two requests
                                  # with equal fields never collide
    rid: int
    tokens: np.ndarray            # prompt (1-D int32)
    max_new: int = 16
    prefix_id: int = -1           # shared-prompt family; -1 = no sharing
    prefix_len: int = -1          # tokens of the prompt that ARE the shared
    #                               prefix (-1 = the whole prompt)
    out: list = field(default_factory=list)
    # scheduling state (set by the core; times are in scheduler steps)
    arrival: float = 0.0
    admitted: float = -1.0
    finished: float = -1.0
    prefill_hit: float = 0.0      # fraction of prompt served from shared
    #                               prefix blocks (paged executor only)


@dataclass
class _Slot:
    req: GenRequest
    idx: int                      # batch row
    prompt: np.ndarray
    base: int                     # position offset (vlm patch prefix)
    pos: int                      # next position to feed
    next_tok: int                 # token to feed at ``pos``
    kb: object = None             # prefilled KV blocks in transit between
    vb: object = None             # _prefill_slot and admit (paged only)

    @property
    def end(self) -> int:         # first generated-token position
        return self.base + len(self.prompt)


@dataclass
class EngineCounters:
    """Observability for the continuous batcher (SERVING.md §4)."""
    decode_batches: int = 0       # batched decode_step launches
    slot_steps: int = 0           # occupied-slot decode iterations
    prefill_calls: int = 0
    prefill_tokens: int = 0

    def to_dict(self) -> dict:
        return {"decode_batches": self.decode_batches,
                "slot_steps": self.slot_steps,
                "prefill_calls": self.prefill_calls,
                "prefill_tokens": self.prefill_tokens}


class _ModelExecutor(Executor):
    """Token plumbing shared by the paged and dense executors: chunked
    prefill at admission, then one decode token per step per slot."""

    def __init__(self, cfg: ModelConfig, params, ctx: MeshCtx,
                 max_batch: int, max_seq: int, prefill_chunk: int):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.chunk = prefill_chunk
        self.slots: list = [None] * max_batch
        self._of: dict = {}                 # id(req) -> _Slot
        self.counters = EngineCounters()

    # subclass interface -----------------------------------------------------
    def _prefill_slot(self, s: _Slot, n_tokens: int):
        """Prefill ``prompt[:n_tokens]`` into slot ``s``'s cache; return
        host logits (V,) for position ``base + n_tokens - 1``."""
        raise NotImplementedError

    def _decode_batch(self, toks: np.ndarray, poss: np.ndarray):
        """One decode step for all rows; return host logits (B, V)."""
        raise NotImplementedError

    # Executor hooks ---------------------------------------------------------
    def admit(self, req: GenRequest, now: float) -> None:
        idx = self.slots.index(None)
        prompt = np.asarray(req.tokens, np.int32).reshape(-1)
        s = _Slot(req=req, idx=idx, prompt=prompt,
                  base=self.cfg.n_patches, pos=0, next_tok=int(prompt[0]))
        self.slots[idx] = s
        self._of[id(req)] = s
        c = min(len(prompt), self.chunk)
        try:
            logits = self._prefill_slot(s, c)
        except BaseException:
            self._drop(s)               # a failed admit must not wedge
            raise                       # the slot (core requeues req)
        self.counters.prefill_calls += 1
        self.counters.prefill_tokens += c
        s.pos = s.base + c
        if s.pos >= s.end:                  # prompt fully prefilled:
            t = int(np.argmax(logits))      # logits predict 1st output
            req.out.append(t)
            s.next_tok = t
        else:                               # chunked: keep feeding prompt
            s.next_tok = int(prompt[c])

    def work(self, active: list, now: float) -> list:
        done = []
        live = []
        for s in self.slots:
            if s is None:
                continue
            if len(s.req.out) >= s.req.max_new:   # finished at admission
                done.append(s.req)
                self._drop(s)
            else:
                live.append(s)
        if not live:
            return done
        toks = np.zeros((self.max_batch,), np.int32)
        poss = np.zeros((self.max_batch,), np.int32)
        for s in live:
            toks[s.idx] = s.next_tok
            poss[s.idx] = s.pos
        logits = self._decode_batch(toks, poss)
        self.counters.decode_batches += 1
        self.counters.slot_steps += len(live)
        for s in live:
            s.pos += 1
            if s.pos >= s.end:              # a generated-token position
                t = int(np.argmax(logits[s.idx]))
                s.req.out.append(t)
                s.next_tok = t
                if len(s.req.out) >= s.req.max_new:
                    done.append(s.req)      # early exit: slot freed now
                    self._drop(s)
            else:                           # still consuming the prompt
                s.next_tok = int(s.prompt[s.pos - s.base])
        return done

    def _drop(self, s: _Slot) -> None:
        self.slots[s.idx] = None
        del self._of[id(s.req)]
        self._on_drop(s)

    def _on_drop(self, s: _Slot) -> None:
        """Subclass hook: slot-level state to clear when a slot frees."""


class PagedModelExecutor(_ModelExecutor):
    """Block-table paged KV executor (SERVING.md §3).

    Pools are (P, L, block, KV, hd) device arrays indexed by block id;
    the host-side ``PagedKVPool`` owns allocation, pinning and the
    prefix-cache LRU. Full prompt-prefix blocks are shared copy-free
    between same-``prefix_id`` requests and retained (LRU) after release.
    """

    def __init__(self, cfg, params, ctx, max_batch, max_seq, prefill_chunk,
                 block_size: int, pool_blocks: int):
        super().__init__(cfg, params, ctx, max_batch, max_seq, prefill_chunk)
        assert D_.paged_supported(cfg, max_seq), cfg.name
        assert max_seq % block_size == 0, (max_seq, block_size)
        self.block = block_size
        self.nb = max_seq // block_size
        self.pool = PagedKVPool(pool_blocks, reserve_null=True)
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        shape = (pool_blocks, L, block_size, KV, hd)
        self.k_pool = jnp.zeros(shape, cfg.dtype)
        self.v_pool = jnp.zeros(shape, cfg.dtype)
        self.table = np.zeros((max_batch, self.nb), np.int32)

        def _prefill(p, toks, last):
            logits, cache = D_.prefill_step(p, {"tokens": toks}, cfg, ctx,
                                            last_index=last)
            kb, vb = D_.cache_to_blocks(cache, block_size)
            return logits[0], kb, vb
        # one jit; per-bucket shapes compile on first use
        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(
            lambda p, kp, vp, tb, po, tk: D_.paged_decode_step(
                p, kp, vp, tb, po, tk, cfg, ctx),
            donate_argnums=(1, 2))

    def _prefix_blocks(self, req: GenRequest, L: int) -> int:
        """Full blocks covered by the request's declared shared prefix —
        the only blocks that may be shared or cached under its
        ``prefix_id`` (SERVING.md §3)."""
        pl = L if req.prefix_len < 0 else min(req.prefix_len, L)
        return pl // self.block

    def admit(self, req: GenRequest, now: float) -> None:
        L = len(np.asarray(req.tokens).reshape(-1))
        # last written position is L + max_new - 2: the final generated
        # token is appended, never fed back
        total = math.ceil((L + req.max_new - 1) / self.block)
        total = max(total, 1)
        owner = id(req)
        shared = (self.pool.share(owner, req.prefix_id,
                                  self._prefix_blocks(req, L))
                  if req.prefix_id >= 0 else [])
        try:
            ids = shared + self.pool.alloc(owner, total - len(shared))
            req.prefill_hit = len(shared) * self.block / max(L, 1)
            super().admit(req, now)         # prefill + token plumbing
        except BaseException:
            self.pool.release(owner)        # unpin this attempt's blocks
            raise
        s = self._of[id(req)]
        row = np.zeros((self.nb,), np.int32)        # null block padding
        row[:total] = ids
        self.table[s.idx] = row
        # Scatter the prefilled chunk's blocks into the pools — but never
        # the shared ones: those already hold the correct prefix KV, and
        # when the chunk ends mid-block the chunk's right padding would
        # overwrite real positions a concurrent sharer is attending over.
        nbp = s.kb.shape[0]
        skip = min(len(shared), nbp)
        if skip < nbp:
            tgt = jnp.asarray(row[skip:nbp])
            self.k_pool = self.k_pool.at[tgt].set(s.kb[skip:])
            self.v_pool = self.v_pool.at[tgt].set(s.vb[skip:])
        s.kb = s.vb = None

    def _prefill_slot(self, s: _Slot, n_tokens: int):
        Lp = math.ceil(n_tokens / self.block) * self.block
        toks = np.zeros((1, Lp), np.int32)
        toks[0, :n_tokens] = s.prompt[:n_tokens]
        last = np.asarray([n_tokens - 1], np.int32)
        logits, s.kb, s.vb = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(last))
        return np.asarray(logits)

    def _decode_batch(self, toks, poss):
        logits, self.k_pool, self.v_pool = self._decode(
            self.params, self.k_pool, self.v_pool,
            jnp.asarray(self.table), jnp.asarray(poss), jnp.asarray(toks))
        return np.asarray(logits)

    def _on_drop(self, s: _Slot) -> None:
        # An empty slot keeps decoding as a dummy row and scatters its
        # garbage block every step: point it back at the null block so
        # the stale ids (now cached prefix blocks, or reallocated) are
        # never written again.
        self.table[s.idx] = 0

    def retire(self, req: GenRequest) -> None:
        L = len(np.asarray(req.tokens).reshape(-1))
        keep = self._prefix_blocks(req, L) if req.prefix_id >= 0 else 0
        self.pool.release(id(req),
                          prefix_id=req.prefix_id if keep else None,
                          keep_blocks=keep)


# cache keys whose axis 2 is the (sliced) sequence axis
_SEQ_KEYS = ("k", "v", "ak", "av", "ckv", "kr", "d_ckv", "d_kr")


class DenseSlotExecutor(_ModelExecutor):
    """Dense per-slot cache fallback for families the paged path does not
    cover (MLA / SSM / hybrid / encdec / vlm / sliding-window rings —
    SERVING.md §3). One persistent ``init_cache(max_batch, max_seq)``
    tree; each admission prefills B=1 and writes its leaves into the
    slot's row, so scheduling behaviour (per-step admission, early exit,
    chunked prefill) is identical to the paged executor."""

    def __init__(self, cfg, params, ctx, max_batch, max_seq, prefill_chunk):
        super().__init__(cfg, params, ctx, max_batch, max_seq, prefill_chunk)
        self.cache = D_.init_cache(cfg, max_batch, max_seq)
        self._decode = jax.jit(
            lambda p, c, t: D_.decode_step(p, c, t, cfg, ctx),
            donate_argnums=(1,))
        # one jit; per-bucket prefill shapes compile on first use
        self._prefill = jax.jit(
            lambda p, b, li: D_.prefill_step(p, b, cfg, ctx, last_index=li))

    def _extras(self, B: int) -> dict:
        cfg = self.cfg
        ex = {}
        if cfg.n_patches:
            ex["patches"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                      cfg.dtype)
        if cfg.is_encoder_decoder:
            ex["frames"] = jnp.zeros((B, cfg.enc_frames, cfg.d_model),
                                     cfg.dtype)
        return ex

    @staticmethod
    def padded_len(cfg: ModelConfig, n_tokens: int) -> int:
        """Prefill length for ``n_tokens``: exact for SSM/hybrid (right
        padding would pollute the order-dependent state recurrence),
        bucketed to 8 elsewhere (fewer jit compiles)."""
        if cfg.family in ("ssm", "hybrid"):
            return n_tokens
        return math.ceil(n_tokens / 8) * 8

    def _prefill_slot(self, s: _Slot, n_tokens: int):
        cfg = self.cfg
        Lp = self.padded_len(cfg, n_tokens)
        Sc = D_.cache_len(cfg, self.max_seq)
        if s.base + Lp > Sc:
            raise ValueError(
                f"prompt chunk {s.base + Lp} exceeds cache window {Sc} "
                f"({cfg.name}); shrink prefill_chunk or raise max_seq")
        toks = np.zeros((1, Lp), np.int32)
        toks[0, :n_tokens] = s.prompt[:n_tokens]
        batch = {"tokens": jnp.asarray(toks), **self._extras(1)}
        last = jnp.asarray([s.base + n_tokens - 1], np.int32)
        logits, c1 = self._prefill(self.params, batch, last)
        self._insert_slot(s.idx, c1, real_pos=s.base + n_tokens)
        return np.asarray(logits[0])

    def _insert_slot(self, i: int, c1: dict, real_pos: int) -> None:
        """Write a B=1 prefill cache into row ``i`` of the global cache."""
        g = dict(self.cache)
        if "slot_pos" in g:                 # stale entries of the slot's
            g["slot_pos"] = g["slot_pos"].at[i].set(-1)   # previous tenant
        for key, leaf in c1.items():
            if key == "pos":
                g["pos"] = g["pos"].at[i].set(real_pos)
            elif key == "slot_pos":
                S = leaf.shape[1]
                g[key] = g[key].at[i, :S].set(leaf[0])
            elif key in _SEQ_KEYS:
                S = leaf.shape[2]
                g[key] = g[key].at[:, i, :S].set(leaf[:, 0])
            else:                           # state / conv / xk / xv
                g[key] = g[key].at[:, i].set(leaf[:, 0])
        self.cache = g

    def _decode_batch(self, toks, poss):
        # cache["pos"] is authoritative on-device; ``poss`` (host mirror)
        # is only used by the shared token plumbing.
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        return np.asarray(logits)


class InferenceEngine:
    """Continuous-batching serving engine over the shared core.

    ``run()`` drives the core until idle and returns the requests that
    finished during this call, in completion order. ``submit()`` may be
    called before or between runs; ``arrival`` (in scheduler steps) may
    be set on the request to stagger availability."""

    def __init__(self, cfg: ModelConfig, params, ctx: MeshCtx | None = None,
                 policy: str = "reciprocating", max_batch: int = 4,
                 max_seq: int = 128, *, block_size: int = 16,
                 pool_blocks: int | None = None,
                 prefill_chunk: int | None = None,
                 paged: bool | None = None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or trivial_ctx()
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.paged = (D_.paged_supported(cfg, max_seq) if paged is None
                      else paged)
        chunk = prefill_chunk or max_seq
        if self.paged:
            nb = max_seq // block_size
            pool_blocks = pool_blocks or 1 + nb * (max_batch + 2)
            self.executor: _ModelExecutor = PagedModelExecutor(
                cfg, params, self.ctx, max_batch, max_seq, chunk,
                block_size, pool_blocks)
        else:
            self.executor = DenseSlotExecutor(
                cfg, params, self.ctx, max_batch, max_seq, chunk)
        self.core = ServeCore(self.executor, policy=policy,
                              max_slots=max_batch, seed=seed)

    @property
    def queue(self):
        return self.core.queue

    @property
    def stats(self) -> ServeStats:
        return self.core.stats

    @property
    def counters(self) -> EngineCounters:
        return self.executor.counters

    @property
    def pool(self) -> PagedKVPool | None:
        return getattr(self.executor, "pool", None)

    def submit(self, req: GenRequest) -> None:
        L = len(np.asarray(req.tokens).reshape(-1))
        if L < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        room = self.max_seq - self.cfg.n_patches
        if L + req.max_new > room:
            raise ValueError(
                f"request {req.rid}: prompt {L} + max_new {req.max_new} "
                f"exceeds max_seq budget {room}")
        if not self.paged:
            # the dense fallback prefills into a cache_len window (< max_seq
            # on sliding-window archs); reject synchronously what admission
            # would only discover at prefill time (and retry forever)
            chunk = min(L, self.executor.chunk)
            need = (self.cfg.n_patches
                    + DenseSlotExecutor.padded_len(self.cfg, chunk))
            window = D_.cache_len(self.cfg, self.max_seq)
            if need > window:
                raise ValueError(
                    f"request {req.rid}: prefill chunk {need} exceeds "
                    f"cache window {window} ({self.cfg.name}); shorten "
                    f"the prompt or set prefill_chunk <= {window}")
        self.core.submit(req)

    def run(self) -> list:
        """Serve everything queued; returns the requests finished by this
        call in completion order."""
        n0 = len(self.core.stats.finished)
        while self.core.has_work():
            self.core.step()
        return self.core.stats.finished[n0:]
