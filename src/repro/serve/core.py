"""Slot-level continuous-batching core shared by every serving frontend.

This module is the scheduling half of the serving architecture
(SERVING.md §1): one admission core, two frontends. The model-backed
engine (`serve/engine.py`) and the discrete-time simulator
(`serve/scheduler.py`) both drive a ``ServeCore``; they differ only in
the ``Executor`` plugged into it. Policy, fairness and residency numbers
measured on the simulator are therefore claims about the same admission
code the model engine runs.

The core owns *scheduling state only*:

* the arrival queue — an ``AdmissionQueue`` from ``core/admission.py``
  (the paper's arrival-stack / entry-segment discipline, or a FIFO/LIFO
  foil);
* the slot roster — at most ``max_slots`` requests are *active*; a slot
  freed by a finished request is refilled on the next step (per-step
  admission, not detached static batches);
* time — one ``step()`` is one decode iteration for every active slot;
* completion stats.

The executor owns *work state*: what "prefill" and "one decode step" cost
(simulator) or compute (model engine + paged KV pool). The protocol is
four hooks; ``work()`` returning a request signals completion, which is
what makes per-request early exit structural rather than bolted on — the
core retires the request and refills the slot the same step
(SERVING.md §4).

A request object must carry ``arrival`` / ``admitted`` / ``finished``
floats (set to ``-1.0`` when unset); both ``serve.scheduler.Request`` and
``serve.engine.GenRequest`` do.

Bookkeeping is amortized O(1) per request, not O(n) per step: pending
requests sit in an arrival-ordered heap (each is pushed and popped
exactly once, instead of the whole backlog being rescanned every step)
and active requests live in an id-keyed dict (retiring one is a dict
delete, not a ``list.remove`` identity scan). ``bookkeeping_ops`` counts
those heap/dict touches so harnesses can assert the O(requests) bound on
million-request traces (the gateway suite does — SERVING.md §8).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

from repro.core.admission import POLICIES, AdmissionQueue


class Executor:
    """Work-model protocol plugged into ``ServeCore`` (SERVING.md §1)."""

    def on_arrival(self, req, now: float) -> None:
        """Request became visible to the scheduler (pre-admission)."""

    def admit(self, req, now: float) -> None:
        """Request won a slot: set up its work state (prefill plan, KV
        blocks, prefix-hit accounting)."""
        raise NotImplementedError

    def work(self, active: list, now: float) -> list:
        """Advance every active request by one step; return the subset
        that finished this step."""
        raise NotImplementedError

    def retire(self, req) -> None:
        """Request left its slot: release resources (KV blocks)."""


@dataclass
class ServeStats:
    finished: list = field(default_factory=list)

    def summary(self) -> dict:
        if not self.finished:
            return {}
        waits = sorted(r.admitted - r.arrival for r in self.finished)
        hits = [r.prefill_hit for r in self.finished]
        lat = sorted(r.finished - r.arrival for r in self.finished)
        n = len(waits)
        return {
            "n": n,
            "mean_wait": sum(waits) / n,
            "p50_wait": waits[n // 2],
            "p99_wait": waits[min(n - 1, int(n * 0.99))],
            "max_wait": waits[-1],
            "p99_latency": lat[min(n - 1, int(n * 0.99))],
            "prefix_hit_rate": sum(hits) / n,
            "throughput_rps": n / max(max(r.finished for r in self.finished),
                                      1e-9),
        }


class DrainStalled(RuntimeError):
    """``drain()`` ran out of steps with work still queued — the workload
    does not fit the step budget (or the executor never finishes it)."""


class ServeCore:
    """The continuous batcher: per-step admission into freed slots."""

    def __init__(self, executor: Executor, policy: str = "reciprocating",
                 max_slots: int = 8, seed: int = 0):
        self.executor = executor
        self.queue: AdmissionQueue = POLICIES[policy](seed)
        self.policy = policy
        self.max_slots = max_slots
        self._pending: list = []        # heap of (arrival, seq, req)
        self._seq = 0                   # heap tiebreak = submission order
        self._active: dict = {}         # id(req) -> req (insertion order)
        self.bookkeeping_ops = 0        # heap pops + slot retirements
        self.stats = ServeStats()
        self.time = 0.0

    @property
    def pending(self) -> list:
        """Submitted-but-not-arrived requests in arrival order (a view —
        the backing store is the arrival heap)."""
        return [r for _, _, r in sorted(self._pending)]

    @property
    def active(self) -> list:
        """Admitted requests in admission order (a view — the backing
        store is the id-keyed slot dict)."""
        return list(self._active.values())

    @property
    def backlog(self) -> int:
        """Requests anywhere in this core (pending + queued + active) —
        O(1), unlike the sorted ``pending`` view. The fleet gateway uses
        this as the per-replica load signal (SERVING.md §8)."""
        return len(self._pending) + len(self.queue) + len(self._active)

    def submit(self, req) -> None:
        """Requests become visible at ``req.arrival`` (O(1) doorway:
        arrival-stack push happens then, not now)."""
        self._seq += 1
        heapq.heappush(self._pending, (req.arrival, self._seq, req))

    def has_work(self) -> bool:
        return bool(self._active or len(self.queue) or self._pending)

    def step(self) -> None:
        """One scheduler tick == one decode iteration for every slot:
        arrivals -> admissions into free slots -> one unit of work."""
        self.time += 1.0
        while self._pending and self._pending[0][0] <= self.time:
            _, _, r = heapq.heappop(self._pending)
            self.bookkeeping_ops += 1
            self.executor.on_arrival(r, self.time)
            self.queue.push(r)
        while len(self._active) < self.max_slots:
            r = self.queue.pop()
            if r is None:
                break
            try:
                r.admitted = self.time
                self.executor.admit(r, self.time)
            except BaseException:
                # never lose the request: it re-queues on the next step
                # (the error still surfaces to the caller)
                r.admitted = -1.0
                self._seq += 1
                heapq.heappush(self._pending, (self.time, self._seq, r))
                raise
            self._active[id(r)] = r
        for r in self.executor.work(list(self._active.values()), self.time):
            r.finished = self.time
            self.executor.retire(r)
            del self._active[id(r)]
            self.bookkeeping_ops += 1
            self.stats.finished.append(r)

    def drain(self, max_steps: int = 1_000_000) -> None:
        """Run until idle. Raises ``DrainStalled`` (never silently
        returns) if ``max_steps`` is exhausted with work still queued."""
        steps = 0
        while self.has_work():
            if steps >= max_steps:
                raise DrainStalled(
                    f"drain({max_steps=}) exhausted with "
                    f"{len(self.active)} active, {len(self.queue)} queued, "
                    f"{len(self.pending)} pending requests")
            self.step()
            steps += 1
