"""Synthetic multi-tenant serving traces for the fleet gateway
(SERVING.md §8).

Real gateway traffic has three kinds of structure a uniform-random trace
would erase, and each one matters for routing:

* **shared prefixes** — requests belong to *tenants*, and every request
  from a tenant opens with that tenant's system prompt (the same token
  blocks, every time). This is what prefix-aware routing exploits: land
  a tenant's traffic on the replica already holding its prompt blocks.
  Tenant weights are Zipf-distributed, so a few hot tenants dominate —
  the regime where affinity pays and random routing shreds the cache.
* **bursty arrivals** — requests come in Poisson bursts (a tenant's
  users pile on together), not an even drizzle. Bursts are what stress
  the dispatch discipline: the ``reciprocating`` router's entry segment
  batches a burst and drains it with bounded bypass.
* **heavy-tailed lengths** — decode lengths are lognormal: most
  responses are short, a few are very long and occupy slots for
  thousands of steps. The tail is what creates load imbalance for
  affinity-only routing to trade off against.

Everything is seeded and streamed: ``generate(...)`` yields
``TraceRequest``s in nondecreasing arrival order, one at a time, so a
million-request trace costs O(burst) memory, not O(trace). Token ids are
materialized lazily per request (the tenant prompt array is shared; only
the unique suffix is fresh) and the gateway drops them after routing.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np


@dataclass(eq=False)            # identity semantics, like GenRequest:
class TraceRequest:             # the core keys slots on id(req)
    rid: int
    arrival: float
    tenant: int
    tokens: np.ndarray | None   # full prompt (tenant prefix + unique tail)
    prompt_tokens: int          # == len(tokens); survives tokens=None
    shared_tokens: int          # tenant-prefix portion of the prompt
    decode_tokens: int
    # runtime (set by the gateway / executor)
    admitted: float = -1.0
    finished: float = -1.0
    first_token: float = -1.0
    prefill_hit: float = 0.0
    replica: int = -1
    # routing state (set at dispatch)
    chain: list | None = None   # prefix-tree node ids for the prompt
    _prefill_left: float = 0.0
    _decode_left: int = 0


@dataclass
class TraceSpec:
    """Knobs for one synthetic tenant mix (all rates are per step)."""
    n_requests: int = 10_000
    n_tenants: int = 160
    zipf_s: float = 1.1             # tenant popularity skew
    shared_blocks: tuple = (4, 12)  # tenant system-prompt size range
    unique_blocks: tuple = (0, 4)   # per-request unique prompt tail range
    block_tokens: int = 16
    burst_rate: float = 0.2         # bursts per step (Poisson)
    burst_size: float = 6.0         # mean extra requests/burst (geometric)
    burst_width: float = 4.0        # steps a burst's arrivals spread over
    decode_mu: float = 3.2          # lognormal decode length (median ~25)
    decode_sigma: float = 0.8
    decode_cap: int = 512
    seed: int = 0
    # Defaults target ~1.4 requests/step: an 8-replica x 8-slot fleet
    # serves ~1.8 req/step (mean decode ~34 steps + ~1-2 prefill), so
    # the fleet runs ~80% loaded — queues form, waits differentiate
    # routers, but the trace drains.


def generate(spec: TraceSpec):
    """Yield ``TraceRequest``s in nondecreasing arrival order.

    Bursts are drawn on a Poisson clock; each burst belongs to one
    Zipf-weighted tenant and scatters a geometric number of requests
    over ``burst_width`` steps. A small heap reorders arrivals across
    overlapping bursts; it holds only the not-yet-safe tail, so memory
    is O(concurrent bursts), independent of ``n_requests``."""
    rng = np.random.default_rng(spec.seed)
    lo_s, hi_s = spec.shared_blocks
    lo_u, hi_u = spec.unique_blocks
    bt = spec.block_tokens

    # Tenant catalogue: popularity + a fixed shared system prompt each.
    # Token ids are partitioned by tenant (tenant t draws from [t*M,
    # (t+1)*M)) so two tenants never alias a block by accident.
    weights = 1.0 / np.arange(1, spec.n_tenants + 1) ** spec.zipf_s
    weights /= weights.sum()
    vocab_per_tenant = 100_000
    prompts = []
    for t in range(spec.n_tenants):
        blocks = int(rng.integers(lo_s, hi_s + 1))
        prompts.append(rng.integers(t * vocab_per_tenant,
                                    (t + 1) * vocab_per_tenant,
                                    size=blocks * bt, dtype=np.int32))

    heap: list = []             # (arrival, rid, req) — reorder buffer
    rid = 0
    t_now = 0.0
    emitted = 0
    while emitted < spec.n_requests:
        if rid < spec.n_requests:
            # next burst start, then scatter its members
            t_now += rng.exponential(1.0 / spec.burst_rate)
            tenant = int(rng.choice(spec.n_tenants, p=weights))
            size = min(1 + rng.geometric(1.0 / spec.burst_size),
                       spec.n_requests - rid)
            offsets = np.sort(rng.uniform(0.0, spec.burst_width, size))
            shared = prompts[tenant]
            for off in offsets:
                uniq = int(rng.integers(lo_u, hi_u + 1)) * bt
                tail = rng.integers(spec.n_tenants * vocab_per_tenant,
                                    spec.n_tenants * vocab_per_tenant * 2,
                                    size=uniq, dtype=np.int32)
                tokens = np.concatenate([shared, tail]) if uniq else shared
                decode = int(min(spec.decode_cap, 1 + rng.lognormal(
                    spec.decode_mu, spec.decode_sigma)))
                req = TraceRequest(
                    rid=rid, arrival=float(t_now + off), tenant=tenant,
                    tokens=tokens, prompt_tokens=len(tokens),
                    shared_tokens=len(shared), decode_tokens=decode)
                heapq.heappush(heap, (req.arrival, rid, req))
                rid += 1
        # Everything that arrived before the next possible burst start
        # (t_now) is safely ordered — later bursts begin at > t_now.
        safe_until = t_now if rid < spec.n_requests else float("inf")
        while heap and heap[0][0] <= safe_until:
            _, _, req = heapq.heappop(heap)
            emitted += 1
            yield req
