"""jit'd public wrappers around the Pallas kernels.

On-CPU (this container) the wrappers run the kernels under
``interpret=True`` for validation; on TPU they compile via Mosaic. Both
kernels get a ``jax.custom_vjp`` whose backward falls back to the
differentiable pure-jnp reference (recompute-based — the standard pattern
until dedicated backward kernels land; forward is the serving-critical
path)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ssd import ssd_scan_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, schedule="serpentine",
                    block_q=128, block_k=128):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               schedule=schedule, block_q=block_q,
                               block_k=block_k, interpret=_on_cpu())


def _fa_fwd(q, k, v, causal, window, schedule, block_q, block_k):
    out = flash_attention(q, k, v, causal, window, schedule, block_q,
                          block_k)
    return out, (q, k, v)


def _fa_bwd(causal, window, schedule, block_q, block_k, resid, g):
    q, k, v = resid
    _, vjp = jax.vjp(
        lambda q, k, v: REF.attention_ref(q, k, v, causal=causal,
                                          window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_scan(x, dt, a_log, bmat, cmat, chunk=128):
    return ssd_scan_fwd(x, dt, a_log, bmat, cmat, chunk=chunk,
                        interpret=_on_cpu())


def _ssd_fwd(x, dt, a_log, bmat, cmat, chunk):
    return ssd_scan(x, dt, a_log, bmat, cmat, chunk), (x, dt, a_log, bmat,
                                                       cmat)


def _ssd_bwd(chunk, resid, g):
    x, dt, a_log, bmat, cmat = resid
    _, vjp = jax.vjp(
        lambda *a: REF.ssd_ref(*a, chunk=chunk), x, dt, a_log, bmat, cmat)
    return vjp(g)


ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)
