"""FlashAttention-2 Pallas TPU kernel with a *reciprocating* KV schedule.

The paper's §9/App. C insight: a palindromic (boustrophedonic) service order
beats FIFO re-scanning whenever a decaying cache is shared — residual
residency is maximized at the turn. The TPU analogue is the Pallas grid
pipeline: when two consecutive grid steps map a block to the same HBM
region, the DMA is elided (the block is already resident in VMEM).

With q-blocks outer and kv-blocks inner, the classic schedule re-scans KV
ascending for every q row: the last KV block of row i and the first KV
block of row i+1 differ => every row boundary refetches. The
``serpentine`` schedule reverses direction on alternate rows (exactly the
paper's palindrome): the boundary block is *revisited* and its fetch is
elided — (n_q - 1) KV+V block fetches saved per (batch, head), plus better
pipeline overlap at the turn. Online softmax is order-invariant, so the
result is identical.

Layouts: q (B, H, Sq, hd); k, v (B, KV, Sk, hd) — GQA is handled by the
index map (head h reads kv head h // (H // KV)); no materialized repeat.
Causal and sliding-window masking compose; fully-masked blocks contribute
zeros (the hillclimb pass adds block skipping).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# named TPUCompilerParams before the pallas API graduated the prefix
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

F32 = jnp.float32
NEG_INF = -1e30


def kv_visit_index(qi, ki, n_kv: int, schedule: str):
    """Actual kv block visited at inner step ki of q row qi (works on both
    python ints and traced scalars)."""
    if schedule == "serpentine":
        rev = qi % 2 == 1
        fwd_ki = ki
        rev_ki = n_kv - 1 - ki
        if isinstance(rev, bool):
            return rev_ki if rev else fwd_ki
        return jax.lax.select(rev, rev_ki, fwd_ki)
    return ki


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, n_kv, block_q, block_k, schedule,
            sq_valid, sk_valid):
    qi = pl.program_id(2)
    kis = pl.program_id(3)
    ki = kv_visit_index(qi, kis, n_kv, schedule)

    @pl.when(kis == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(F32)                      # (bq, hd)
    k = k_ref[0, 0].astype(F32)                      # (bk, hd)
    v = v_ref[0, 0].astype(F32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kv_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = (q_pos < sq_valid) & (kv_pos < sk_valid)
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=F32))
    m_scr[...] = m_new

    @pl.when(kis == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        schedule="serpentine", block_q=128, block_k=128,
                        interpret=False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    n_q = (Sq + pq) // block_q
    n_kv = (Sk + pk) // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, n_kv=n_kv,
        block_q=block_q, block_k=block_k, schedule=schedule,
        sq_valid=Sq, sk_valid=Sk)

    def kv_map(b, h, qi, ki):
        return (b, h // G, kv_visit_index(qi, ki, n_kv, schedule), 0)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), kv_map),
            pl.BlockSpec((1, 1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q, hd), F32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]


# ---------------------------------------------------------------------------
# structural DMA accounting (the serpentine win, measured from index maps)
# ---------------------------------------------------------------------------
def count_kv_fetches(n_q: int, n_kv: int, schedule: str) -> int:
    """Walk the grid order and count HBM->VMEM KV fetches, eliding
    repeats of the immediately previous block (Pallas pipeline rule)."""
    fetches, prev = 0, None
    for qi in range(n_q):
        for kis in range(n_kv):
            ki = kv_visit_index(qi, kis, n_kv, schedule)
            if ki != prev:
                fetches += 1
            prev = ki
    return fetches


def serpentine_savings(n_q: int, n_kv: int) -> dict:
    asc = count_kv_fetches(n_q, n_kv, "ascending")
    ser = count_kv_fetches(n_q, n_kv, "serpentine")
    return {"ascending": asc, "serpentine": ser,
            "saved_fraction": (asc - ser) / asc}
