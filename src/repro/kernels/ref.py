"""Pure-jnp oracles for every Pallas kernel (the test ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import ssd_chunked

F32 = jnp.float32


def attention_ref(q, k, v, *, causal=True, window=0):
    """Exact softmax attention. q: (B,H,Sq,hd); k/v: (B,KV,Sk,hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(F32), kk.astype(F32))
    s = s / np.sqrt(hd)
    qp, kp = jnp.arange(Sq)[:, None], jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kp <= qp
    if window:
        m &= kp > qp - window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(F32)).astype(q.dtype)


def ssd_ref(x, dt, a_log, bmat, cmat, *, chunk=64):
    """SSD oracle — the model-zoo reference implementation itself."""
    y, _ = ssd_chunked(x, dt, a_log, bmat, cmat, chunk)
    return y


def ssd_ref_sequential(x, dt, a_log, bmat, cmat):
    """Slow fully-sequential SSM recurrence (oracle for the oracle)."""
    B, S, H, Pd = x.shape
    A = -jnp.exp(a_log.astype(F32))

    def step(state, inp):
        xt, dtt, bt, ct = inp
        a = jnp.exp(dtt.astype(F32) * A[None, :])            # (B,H)
        bx = xt.astype(F32) * dtt.astype(F32)[..., None]
        state = state * a[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", bt.astype(F32), bx)
        y = jnp.einsum("bn,bhnp->bhp", ct.astype(F32), state)
        return state, y

    state0 = jnp.zeros((B, H, bmat.shape[-1], Pd), F32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          bmat.transpose(1, 0, 2), cmat.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
