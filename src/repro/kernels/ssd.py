"""Mamba-2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

Grid: (batch, n_chunks) with the chunk dimension sequential ("arbitrary");
the inter-chunk recurrent state lives in VMEM scratch and is carried across
grid steps — the HBM working set per step is one chunk of x/dt/B/C, and the
O(S) state recurrence never round-trips through HBM (the pure-jnp reference
in ``repro.models.ssm`` materializes per-chunk states; this kernel is the
perf-critical fusion for the mamba2/zamba2 architectures).

The SSD recurrence is order-dependent, so the paper's serpentine schedule
does not apply here (documented in DESIGN.md); the reciprocating insight
lands in this kernel family via the flash-attention KV schedule instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# named TPUCompilerParams before the pallas API graduated the prefix
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

F32 = jnp.float32


def _kernel(alog_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, st_scr, *,
            chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    A = -jnp.exp(alog_ref[...].astype(F32))          # (H,)
    x = x_ref[0].astype(F32)                          # (Q, H, P)
    dt = dt_ref[0].astype(F32)                        # (Q, H)
    bq = b_ref[0].astype(F32)                         # (Q, N)
    cq = c_ref[0].astype(F32)                         # (Q, N)

    la = dt * A[None, :]                              # (Q, H) log decay
    bx = x * dt[..., None]                            # (Q, H, P)
    cum = jnp.cumsum(la, axis=0)                      # (Q, H)
    total = cum[-1:, :]                               # (1, H)

    # intra-chunk (masked attention-like term)
    cb = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)   # (Q, Q)
    seg = cum[:, None, :] - cum[None, :, :]           # (Q, Q, H)
    iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (iota >= iota_j)[..., None]
    m = jnp.where(causal, jnp.exp(seg), 0.0) * cb[..., None]
    y = jnp.einsum("ijh,jhp->ihp", m, bx, preferred_element_type=F32)

    # inter-chunk: carried state contribution
    state = st_scr[...]                               # (H, N, P)
    decay_in = jnp.exp(cum)                           # (Q, H)
    y += jnp.einsum("in,hnp,ih->ihp", cq, state, decay_in,
                    preferred_element_type=F32)

    # state update
    decay_out = jnp.exp(total - cum)                  # (Q, H)
    inj = jnp.einsum("jn,jhp,jh->hnp", bq, bx, decay_out,
                     preferred_element_type=F32)
    st_scr[...] = state * jnp.exp(total)[0, :, None, None] + inj

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_fwd(x, dt, a_log, bmat, cmat, *, chunk=128, interpret=False):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); bmat/cmat: (B,S,N).
    Returns y (B,S,H,P)."""
    B, S, H, Pd = x.shape
    N = bmat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, H, Pd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, Pd), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, Pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, N, Pd), F32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a_log, x, dt, bmat, cmat)
    return y
