"""Core transformer layers: norms, RoPE, attention, MLP, MoE.

Attention comes in two flavours:

* ``chunked_attention`` — FlashAttention2-style online softmax over KV
  chunks, expressed with ``jax.lax.scan`` (pure jnp; the Pallas kernel in
  ``repro.kernels.flash_attention`` implements the same contract for TPU and
  is validated against this code path).
* ``decode_attention`` — single-query attention over a (possibly
  sequence-sharded) KV cache; GSPMD turns the softmax reductions over the
  sharded seq axis into all-reduces (flash-decoding style).

The MoE block is an explicit shard_map EP(+expert-TP) hybrid:
``ep = gcd(n_experts, model_axis)`` expert-parallel groups x
``tpi = model_axis // ep``-way tensor parallel within each expert, with
all_to_all token dispatch/return. ``tpi == 1`` degenerates to pure EP
(deepseek-v2: 160 experts / 16 chips); mixtral (8 experts / 16 chips) runs
ep=8 x tpi=2 with the partial-sum-on-return trick (no grouped psum needed).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from repro.sharding.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.sharding.ctx import MeshCtx

F32 = jnp.float32
NEG_INF = -1e30


def tree_index(tree, i):
    """Slice every leaf of a pytree at index ``i`` along axis 0 (binds
    ``i`` eagerly, safe inside python loops)."""
    return jax.tree.map(lambda a: a[i], tree)


def scan_or_unroll(step, carry, xs, *, scan: bool, length: int | None = None):
    """lax.scan, or an unrolled python loop (dry-run mode, so XLA's cost
    analysis sees every iteration — while-loop bodies are counted once)."""
    if scan:
        return jax.lax.scan(step, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = None if xs is None else tree_index(xs, i)
        carry, y = step(carry, x_i)
        ys.append(y)
    ys = (jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
          if ys and jax.tree.leaves(ys[0]) else None)
    return carry, ys


# ---------------------------------------------------------------------------
# norms / positions
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps: float = 1e-5):
    h = x.astype(F32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * w.astype(F32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = positions[..., :, None].astype(F32) * freqs            # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d_model: int, dtype):
    """Additive sinusoidal positions (whisper-style stub)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=F32)
                    / max(half - 1, 1))
    ang = positions[..., None].astype(F32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# attention (train / prefill): chunked online softmax
# ---------------------------------------------------------------------------
def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_start=0, kv_len: int | None = None,
                      chunk: int = 1024, unroll: bool = False):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). GQA via head grouping.

    Online-softmax scan over KV chunks; fp32 accumulators. ``window`` > 0
    adds a sliding-window lower bound. ``kv_len`` masks ragged tails after
    padding Sk up to a chunk multiple.
    """
    B, Sq, H, hd = q.shape
    Bk, Sk, KV, _ = k.shape
    hdv = v.shape[-1]                     # may differ from hd (MLA)
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    chunk = min(chunk, Sk)
    if unroll:                       # cap the unrolled body count at 16
        chunk = max(chunk, (Sk + 15) // 16)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = Sk
    n_chunks = (Sk + pad) // chunk

    qg = q.reshape(B, Sq, KV, G, hd)
    q_pos = q_start + jnp.arange(Sq)

    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, hdv).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        kci, vci, c_start = xs
        # scores: (B, KV, G, Sq, chunk)
        s = jnp.einsum("bqkgh,bckh->bkgqc", qg.astype(F32), kci.astype(F32),
                       preferred_element_type=F32) * scale
        kv_pos = c_start + jnp.arange(chunk)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        if kv_len is not None:
            mask &= (kv_pos < kv_len)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckh->bkgqh", p, vci.astype(F32),
                        preferred_element_type=F32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    init = (jnp.full((B, KV, G, Sq), NEG_INF, F32),
            jnp.zeros((B, KV, G, Sq), F32),
            jnp.zeros((B, KV, G, Sq, hdv), F32))
    c_starts = jnp.arange(n_chunks) * chunk
    # checkpoint the chunk body: scan-AD would otherwise stack the per-chunk
    # score/probability residuals (B,KV,G,Sq,chunk f32 x n_chunks — ~60 GB
    # for deepseek train_4k) for backward; recomputing them per chunk is the
    # flash-attention trade (EXPERIMENTS §Perf, deepseek cell / iter 1).
    body_fn = body if unroll else jax.checkpoint(body)
    (m, l, acc), _ = scan_or_unroll(body_fn, init, (kc, vc, c_starts),
                                    scan=not unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hdv).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention (decode): one query over a cache
# ---------------------------------------------------------------------------
def decode_attention(q, k, v, slot_pos, pos):
    """q: (B, 1, H, hd); k, v: (B, S, KV, hd); slot_pos: (B, S) int32
    absolute position held by each cache slot (-1 = empty). ``pos`` is the
    current decode position (B,). Seq-sharded caches work transparently:
    the max/sum reductions become all-reduces under GSPMD."""
    B, _, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(F32), k.astype(F32),
                   preferred_element_type=F32) * scale
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(F32),
                     preferred_element_type=F32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_swiglu(x, wg, wu, wd):
    g = jnp.einsum("...d,df->...f", x, wg)
    u = jnp.einsum("...d,df->...f", x, wu)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, wd)


def mlp_gelu(x, wi, wd):
    h = jnp.einsum("...d,df->...f", x, wi)
    h = jax.nn.gelu(h.astype(F32), approximate=True).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, wd)


# ---------------------------------------------------------------------------
# MoE: shard_map EP(+TP) hybrid with all_to_all dispatch
# ---------------------------------------------------------------------------
def moe_topology(n_experts: int, model_size: int) -> tuple[int, int, int]:
    """Returns (ep, tpi, e_loc): expert-parallel groups, intra-expert TP
    degree, experts per group."""
    ep = math.gcd(n_experts, model_size)
    tpi = model_size // ep
    e_loc = n_experts // ep
    return ep, tpi, e_loc


def moe_capacity(tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25) -> int:
    c = int(math.ceil(tokens * top_k / n_experts * capacity_factor))
    return max(8, (c + 7) // 8 * 8)


def _moe_block_local(xt, w_router, wg, wu, wd, *, n_experts, top_k, cap,
                     ep, tpi, e_loc, model_axis):
    """Per-shard body (inside shard_map). xt: (T, D) local tokens.
    wg/wu: (1, e_loc, D, F_t); wd: (1, e_loc, F_t, D)."""
    T, D = xt.shape
    M = ep * tpi

    # --- route -----------------------------------------------------------
    logits = jnp.einsum("td,de->te", xt.astype(F32), w_router.astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, top_k)                     # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- capacity-bounded dispatch buffer (E, cap, D) ----------------------
    flat_ids = ids.reshape(-1)                                  # (T*K,)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_experts))
    pos_in_e = jnp.arange(T * top_k) - starts[sorted_ids]
    slot = jnp.where(pos_in_e < cap, sorted_ids * cap + pos_in_e,
                     n_experts * cap)                           # OOB -> drop
    xs = xt[order // top_k]                                     # (T*K, D)
    buf = jnp.zeros((n_experts * cap, D), xt.dtype).at[slot].set(
        xs, mode="drop")

    # --- all_to_all dispatch: (M, e_loc, cap, D) ----------------------------
    bufg = buf.reshape(ep, e_loc * cap, D)
    send = jnp.repeat(bufg, tpi, axis=0)                        # dup per TP half
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                              concat_axis=0, tiled=True)        # (M, e_loc*cap, D)

    # --- expert GEMMs (batched over local experts) --------------------------
    xr = recv.reshape(M, e_loc, cap, D).transpose(1, 0, 2, 3) \
             .reshape(e_loc, M * cap, D)
    g = jnp.einsum("etd,edf->etf", xr, wg[0])
    u = jnp.einsum("etd,edf->etf", xr, wu[0])
    h = jax.nn.silu(g.astype(F32)).astype(xr.dtype) * u
    part = jnp.einsum("etf,efd->etd", h, wd[0])                 # partial over F_t

    # --- return a2a; sum TP partials on the sender ---------------------------
    back = part.reshape(e_loc, M, cap, D).transpose(1, 0, 2, 3) \
               .reshape(M, e_loc * cap, D)
    ret = jax.lax.all_to_all(back, model_axis, split_axis=0,
                             concat_axis=0, tiled=True)
    out_buf = ret.reshape(ep, tpi, e_loc * cap, D).sum(axis=1) \
                 .reshape(n_experts * cap, D)

    # --- gather back + weighted combine -------------------------------------
    safe = jnp.minimum(slot, n_experts * cap - 1)
    y_sorted = jnp.where((slot < n_experts * cap)[:, None],
                         out_buf[safe], 0.0)
    y_exp = jnp.zeros((T * top_k, D), xt.dtype).at[order].set(y_sorted)
    y = (y_exp.reshape(T, top_k, D).astype(F32)
         * gate[..., None]).sum(axis=1).astype(xt.dtype)

    # --- load-balance aux loss ------------------------------------------------
    frac = jnp.zeros((n_experts,), F32).at[flat_ids].add(1.0) / (T * top_k)
    aux = n_experts * jnp.sum(frac * probs.mean(axis=0))
    return y, aux.reshape(1)


def moe_forward(x, p, cfg, ctx: MeshCtx, capacity_factor: float = 1.25,
                seq_sharded: bool = True):
    """x: (B, S, D), sequence-sharded over the model axis when
    ``seq_sharded`` (train/prefill). Returns (y, aux_loss)."""
    B, S, D = x.shape
    M = ctx.model_size
    ep, tpi, e_loc = moe_topology(cfg.n_experts, M)
    s_loc = S // M if seq_sharded else S
    t_loc = max(1, B // ctx.data_size) * s_loc
    cap = moe_capacity(t_loc, cfg.n_experts, cfg.top_k, capacity_factor)

    body = partial(_moe_block_local, n_experts=cfg.n_experts,
                   top_k=cfg.top_k, cap=cap, ep=ep, tpi=tpi, e_loc=e_loc,
                   model_axis=ctx.model_axis)

    ba = ctx.batch_axes

    def block(xb, w_router, wg, wu, wd):
        # FSDP: expert weights arrive sharded on their embed dim over the
        # data axes; gather them HERE so the all-gather stays inside the
        # layer scan body (hoisting it out of the loop would materialize
        # every layer's experts at once — see DESIGN.md §4).
        wg = jax.lax.all_gather(wg, ba, axis=2, tiled=True)
        wu = jax.lax.all_gather(wu, ba, axis=2, tiled=True)
        wd = jax.lax.all_gather(wd, ba, axis=3, tiled=True)
        bl, sl, d = xb.shape
        y, aux = body(xb.reshape(bl * sl, d), w_router, wg, wu, wd)
        return y.reshape(bl, sl, d), aux

    seq_spec = ctx.model_axis if seq_sharded else None
    y, aux = shard_map(
        block, mesh=ctx.mesh,
        in_specs=(P(ba, seq_spec, None), P(None, None),
                  P(ctx.model_axis, None, ba, None),
                  P(ctx.model_axis, None, ba, None),
                  P(ctx.model_axis, None, None, ba)),
        out_specs=(P(ba, seq_spec, None), P(ba)),
        check_vma=False,
    )(x, p["w_router"], p["wg"], p["wu"], p["wd"])
    return y, aux.mean()


def moe_decode(x1, p, cfg, ctx: MeshCtx):
    """Single-token MoE (decode path, B small). Two regimes:

    * ``B*K <= E`` — gather only the active experts' weights (what a real
      decode engine reads from HBM);
    * otherwise   — every expert is touched by some token: scan all experts
      in their physical (M, e_loc) layout, accumulating masked partials
      (F_t pieces sum exactly because swiglu is elementwise in F).
    """
    B, S, D = x1.shape
    E, K = cfg.n_experts, cfg.top_k
    M = ctx.model_size
    ep, tpi, e_loc = moe_topology(E, M)
    Ft = p["wg"].shape[-1]
    xt = x1.reshape(B * S, D)

    logits = jnp.einsum("td,de->te", xt.astype(F32),
                        p["w_router"].astype(F32))
    gate, ids = jax.lax.top_k(jax.nn.softmax(logits, -1), K)   # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    if B * S * K <= E:
        # ids -> physical rows (g*tpi + h, slot)
        g = ids // e_loc
        slot = ids % e_loc
        pieces = []
        for h in range(tpi):
            m = g * tpi + h                                     # (T,K)
            wg_s = p["wg"][m, slot]                             # (T,K,D,Ft)
            wu_s = p["wu"][m, slot]
            wd_s = p["wd"][m, slot]                             # (T,K,Ft,D)
            gg = jnp.einsum("td,tkdf->tkf", xt, wg_s)
            uu = jnp.einsum("td,tkdf->tkf", xt, wu_s)
            hh = jax.nn.silu(gg.astype(F32)).astype(xt.dtype) * uu
            pieces.append(jnp.einsum("tkf,tkfd->tkd", hh, wd_s))
        y = sum(pieces)                                         # (T,K,D)
        y = (y.astype(F32) * gate[..., None]).sum(1).astype(xt.dtype)
        return y.reshape(B, S, D), jnp.zeros((), F32)

    # dense-all: scan over physical expert slices, masked accumulate
    wg = p["wg"].reshape(M * e_loc, D, Ft)
    wu = p["wu"].reshape(M * e_loc, D, Ft)
    wd = p["wd"].reshape(M * e_loc, Ft, D)

    def body(acc, i):
        m, slot = i // e_loc, i % e_loc
        e = (m // tpi) * e_loc + slot                           # logical id
        w = ((ids == e).astype(F32) * gate).sum(-1)             # (T,)
        gg = jnp.einsum("td,df->tf", xt, wg[i])
        uu = jnp.einsum("td,df->tf", xt, wu[i])
        hh = jax.nn.silu(gg.astype(F32)).astype(xt.dtype) * uu
        yy = jnp.einsum("tf,fd->td", hh, wd[i]).astype(F32)
        return acc + yy * w[:, None], None

    acc, _ = scan_or_unroll(body, jnp.zeros((B * S, D), F32),
                            jnp.arange(M * e_loc), scan=cfg.scan_layers)
    return acc.astype(x1.dtype).reshape(B, S, D), jnp.zeros((), F32)
