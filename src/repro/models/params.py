"""Parameter-spec system.

Each parameter is declared once as a ``Param`` (shape + logical axes +
initializer). From the spec tree we derive, consistently:

* materialized parameters           (``init_params``)
* ShapeDtypeStruct stand-ins        (``abstract_params``) — dry-run, no alloc
* logical-axes tree                 (``logical_axes``) → mesh shardings

Logical axis vocabulary (mapped to mesh axes by ``repro.sharding.rules``):
  "embed"   — model width D            (FSDP'd over data for params)
  "vocab"   — vocabulary               (TP over model)
  "heads"   — attention head blocks    (TP over model)
  "kv_heads"— kv head blocks
  "mlp"     — FFN hidden               (TP over model)
  "experts" — MoE expert dim           (EP over model)
  "layers"  — stacked scan dim         (never sharded; PP would split it)
  None      — replicated dim
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Param(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[Any, ...]           # logical axis name (str) or None per dim
    init: str = "normal"            # normal|zeros|ones|embed
    scale: float = 0.0              # 0 -> 1/sqrt(fan_in) (last-dim-out conv.)

    def fan_in(self) -> int:
        return int(np.prod(self.shape[:-1])) if len(self.shape) > 1 else 1


def is_param(x) -> bool:
    return isinstance(x, Param)


def tree_map_params(fn, specs):
    return jax.tree.map(fn, specs, is_leaf=is_param)


def stack_specs(specs, n: int, axis_name: str = "layers"):
    """Prepend a stacked (scan) dimension to every spec in the subtree."""
    return tree_map_params(
        lambda p: Param((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale),
        specs)


def abstract_params(specs, dtype):
    return tree_map_params(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), specs)


def logical_axes(specs):
    return tree_map_params(lambda p: p.axes, specs)


def init_params(specs, key, dtype):
    """Materialize parameters. Deterministic per-leaf fold of the key."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_param)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for i, p in enumerate(leaves):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            scale = p.scale if p.scale else 1.0 / np.sqrt(max(p.fan_in(), 1))
            if p.init == "embed":
                scale = 0.02
            out.append((jax.random.normal(keys[i], p.shape, jnp.float32)
                        * scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def count_specs(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_param)
    return int(sum(np.prod(p.shape) for p in leaves))
