"""Unified model facade: init/abstract params, train loss, prefill, decode.

Sharding strategy (DESIGN.md §4): activations are sequence-sharded over the
``model`` mesh axis (SP/CP); GQA attention is context-parallel (q
seq-sharded, small GQA KV gathered); MLA attention is head-parallel (128
heads divide every mesh); MoE dispatches through the shard_map EP(+TP)
hybrid in ``layers.moe_forward``; decode KV caches are sequence-sharded
(flash-decoding: softmax reductions become all-reduces). Weights are
FSDP-sharded over ``data`` via the logical axis rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P_
from repro.models import specs as S_
from repro.models.layers import (
    F32, chunked_attention, mlp_gelu, mlp_swiglu, moe_forward,
    rmsnorm, rope, scan_or_unroll, sinusoidal_pos, tree_index,
)
from repro.models.ssm import mamba2_mixer
from repro.sharding.ctx import MeshCtx, constrain as cs


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def param_specs(cfg: ModelConfig, model_size: int = 1):
    return S_.param_specs(cfg, model_size)


def init_params(cfg: ModelConfig, key, model_size: int = 1):
    return P_.init_params(param_specs(cfg, model_size), key, cfg.dtype)


def abstract_params(cfg: ModelConfig, model_size: int = 1):
    return P_.abstract_params(param_specs(cfg, model_size), cfg.dtype)


def logical_axes(cfg: ModelConfig, model_size: int = 1):
    return P_.logical_axes(param_specs(cfg, model_size))


def count_params(cfg: ModelConfig, include_embed: bool = True) -> int:
    total = P_.count_specs(param_specs(cfg, 1))
    if not include_embed:
        emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        total -= emb
    return total


def count_active_params(cfg: ModelConfig, include_embed: bool = True) -> int:
    total = count_params(cfg, include_embed)
    if not cfg.n_experts:
        return total
    n_moe = cfg.n_layers - cfg.first_dense_layers
    routed = n_moe * 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_experts
    active_routed = n_moe * 3 * cfg.d_model * cfg.moe_d_ff * cfg.top_k
    return total - routed + active_routed


# ---------------------------------------------------------------------------
# attention blocks (full-sequence: train / prefill)
# ---------------------------------------------------------------------------
def attn_forward(x, p, cfg, ctx, positions, *, causal, window=0,
                 kv_src=None, kv_positions=None, collect_kv=False):
    """GQA attention, context-parallel. x: (B,S,D). kv_src enables
    cross-attention. Returns (out, (k, v) if collect_kv)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_src is None else kv_src
    Sk = src.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"]).reshape(B, Sk, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"]).reshape(B, Sk, KV, hd)
    if cfg.rope_theta:
        kv_pos = positions if kv_positions is None else kv_positions
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    q = cs(q, ctx, "B", "M", None, None)       # CP: q rows sharded
    k = cs(k, ctx, "B", None, None, None)      # small GQA kv: gathered
    v = cs(v, ctx, "B", None, None, None)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            unroll=not cfg.scan_layers)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), p["wo"])
    return (out, (k, v)) if collect_kv else (out, None)


def mla_forward(x, p, cfg, ctx, positions, *, collect_kv=False):
    """DeepSeek-v2 MLA, head-parallel. Returns (out, (ckv, kr))."""
    B, S, D = x.shape
    H = cfg.n_heads
    R, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    q = rmsnorm(jnp.einsum("bsd,dq->bsq", x, p["wq_a"]), p["q_norm"],
                cfg.norm_eps)
    q = jnp.einsum("bsq,qh->bsh", q, p["wq_b"]).reshape(B, S, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = rope(qr, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv, kr = ckv_full[..., :R], ckv_full[..., R:]
    ckv = rmsnorm(ckv, p["kv_norm"], cfg.norm_eps)
    kr = rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    kv = jnp.einsum("bsr,rh->bsh", ckv, p["wkv_b"]).reshape(B, S, H, dn + dv)
    kn, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, dr))], axis=-1)
    qf = jnp.concatenate([qn, qr], axis=-1)
    # head-parallel: 128 heads divide every mesh
    qf = cs(qf, ctx, "B", None, "M", None)
    k = cs(k, ctx, "B", None, "M", None)
    v = cs(v, ctx, "B", None, "M", None)
    out = chunked_attention(qf, k, v, causal=True,
                            unroll=not cfg.scan_layers)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * dv), p["wo"])
    return (out, (ckv, kr)) if collect_kv else (out, None)


def mlp_forward(x, p, cfg, d_ff_kind="mlp"):
    if "wg" in p:
        return mlp_swiglu(x, p["wg"], p["wu"], p["wd"])
    return mlp_gelu(x, p["wi"], p["wd"])


# ---------------------------------------------------------------------------
# decoder layer (train / prefill), one scan step
# ---------------------------------------------------------------------------
def _dense_or_moe(h, lp, cfg, ctx):
    """FFN sub-block. Returns (delta, aux)."""
    if "moe" in lp:
        p = lp["moe"]
        hn = rmsnorm(h, p["ln"], cfg.norm_eps)
        y, aux = moe_forward(hn, p, cfg, ctx)
        if cfg.n_shared_experts:
            y = y + mlp_swiglu(hn, p["sh_wg"], p["sh_wu"], p["sh_wd"])
        return y, aux
    p = lp["mlp"]
    return mlp_forward(rmsnorm(h, p["ln"], cfg.norm_eps), p, cfg), 0.0


def decoder_layer(x, lp, cfg, ctx, positions, *, collect_kv=False):
    """Returns (x_out, aux, kv) — kv populated when collect_kv."""
    kv = None
    if "mamba" in lp:
        h = rmsnorm(x, lp["mamba"]["ln"], cfg.norm_eps)
        y, (state, conv) = mamba2_mixer(h, lp["mamba"], cfg, ctx)
        x = x + y
        kv = (state, conv) if collect_kv else None
        return cs(x, ctx, "B", "M", None), 0.0, kv
    ap = lp["attn"]
    hn = rmsnorm(x, ap["ln"], cfg.norm_eps)
    if cfg.attention == "mla":
        y, kv = mla_forward(hn, ap, cfg, ctx, positions, collect_kv=collect_kv)
    else:
        y, kv = attn_forward(hn, ap, cfg, ctx, positions, causal=True,
                             window=cfg.sliding_window, collect_kv=collect_kv)
    x = x + y
    y, aux = _dense_or_moe(x, lp, cfg, ctx)
    x = x + y
    return cs(x, ctx, "B", "M", None), aux, kv


def shared_block(x, bp, cfg, ctx, positions, *, collect_kv=False):
    """zamba2 shared attention+MLP block (single weight set)."""
    ap, mp = bp["attn"], bp["mlp"]
    y, kv = attn_forward(rmsnorm(x, ap["ln"], cfg.norm_eps), ap, cfg, ctx,
                         positions, causal=True, collect_kv=collect_kv)
    x = x + y
    x = x + mlp_forward(rmsnorm(x, mp["ln"], cfg.norm_eps), mp, cfg)
    return cs(x, ctx, "B", "M", None), kv


# ---------------------------------------------------------------------------
# embedding / loss
# ---------------------------------------------------------------------------
def embed_tokens(params, tokens, cfg, ctx):
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    return cs(h, ctx, "B", "M", None)


def unembed_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T          # (D, V)
    return params["unembed"]


def xent_loss(h, params, labels, mask, cfg, ctx, chunk: int = 512):
    """Chunked softmax cross-entropy. h: (B,S,D); labels/mask: (B,S)."""
    B, S, D = h.shape
    W = unembed_matrix(params, cfg)
    chunk = min(chunk, S)
    nc = S // chunk

    def body(carry, i):
        loss_sum, cnt = carry
        hc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        mc = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        logits = jnp.einsum("bcd,dv->bcv", hc, W).astype(F32)
        logits = cs(logits, ctx, "B", None, "M")
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        loss_sum = loss_sum + jnp.sum((logz - ll) * mc)
        return (loss_sum + 0.0, cnt + jnp.sum(mc)), None

    (loss_sum, cnt), _ = scan_or_unroll(
        body, (jnp.zeros((), F32), jnp.zeros((), F32)), jnp.arange(nc),
        scan=cfg.scan_layers)
    return loss_sum / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# full forward: decoder-only LM families (dense|moe|ssm|hybrid|vlm)
# ---------------------------------------------------------------------------
def _scan_layers(x, layers_p, cfg, ctx, positions, shared_p=None,
                 collect_kv=False):
    """Scan the homogeneous stacked layers; handles zamba2's shared block.
    Returns (x, aux_total, stacked_kv)."""
    n_scan = jax.tree.leaves(layers_p)[0].shape[0]

    def step(carry, xs):
        x, aux = carry
        i, lp = xs
        if shared_p is not None and cfg.shared_attn_every:
            def with_attn(x):
                y, _ = shared_block(x, shared_p, cfg, ctx, positions)
                return y
            pred = i % cfg.shared_attn_every == 0
            if isinstance(pred, bool):            # unrolled: static branch
                if pred:
                    x = with_attn(x)
            else:
                x = jax.lax.cond(pred, with_attn, lambda x: x, x)
        x, a, kv = decoder_layer(x, lp, cfg, ctx, positions,
                                 collect_kv=collect_kv)
        return (x, aux + a), kv

    step_fn = jax.checkpoint(step) if cfg.remat else step
    if cfg.scan_layers:
        (x, aux), kvs = jax.lax.scan(
            step_fn, (x, jnp.zeros((), F32)), (jnp.arange(n_scan), layers_p))
        return x, aux, kvs
    # unrolled (dry-run): python layer index -> conds resolve statically
    carry, kv_list = (x, jnp.zeros((), F32)), []
    for i in range(n_scan):
        lp = tree_index(layers_p, i)
        carry, kv = step_fn(carry, (i, lp))
        kv_list.append(kv)
    x, aux = carry
    kvs = (jax.tree.map(lambda *zs: jnp.stack(zs), *kv_list)
           if kv_list and jax.tree.leaves(kv_list[0]) else None)
    return x, aux, kvs


def forward_lm(params, batch, cfg, ctx, *, collect_kv=False):
    """Decoder-only forward. batch: tokens (B,S_text) [+ patches (B,P,D)].
    Returns (hidden, aux, caches-dict-pieces)."""
    tokens = batch["tokens"]
    h = embed_tokens(params, tokens, cfg, ctx)
    if cfg.n_patches:   # vlm: splice patch embeddings as a prefix
        patches = batch["patches"].astype(cfg.dtype)
        h = jnp.concatenate([patches, h], axis=1)
        h = cs(h, ctx, "B", "M", None)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    aux = jnp.zeros((), F32)
    dense_kvs = None
    if cfg.first_dense_layers:
        def dense_step(x, lp):
            ap = lp["attn"]
            hn = rmsnorm(x, ap["ln"], cfg.norm_eps)
            y, kv = (mla_forward(hn, ap, cfg, ctx, positions,
                                 collect_kv=collect_kv)
                     if cfg.attention == "mla" else
                     attn_forward(hn, ap, cfg, ctx, positions, causal=True,
                                  window=cfg.sliding_window,
                                  collect_kv=collect_kv))
            x = x + y
            x = x + mlp_forward(rmsnorm(x, lp["mlp"]["ln"], cfg.norm_eps),
                                lp["mlp"], cfg)
            return cs(x, ctx, "B", "M", None), kv
        h, dense_kvs = scan_or_unroll(
            lambda c, lp: dense_step(c, lp), h, params["dense_layers"],
            scan=cfg.scan_layers)

    h, aux, kvs = _scan_layers(h, params["layers"], cfg, ctx, positions,
                               shared_p=params.get("shared_block"),
                               collect_kv=collect_kv)

    # zamba2's shared-attn KV during prefill is recomputed at decode start;
    # for the dry-run serve path we collect it separately (see prefill_step).
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, aux, {"layers": kvs, "dense": dense_kvs}


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------
def forward_encdec(params, batch, cfg, ctx, *, collect_kv=False):
    """batch: frames (B,F,D) stub embeddings + tokens (B,S)."""
    frames = batch["frames"].astype(cfg.dtype)
    tokens = batch["tokens"]
    B, Fr, D = frames.shape
    S = tokens.shape[1]
    epos = jnp.broadcast_to(jnp.arange(Fr), (B, Fr))
    dpos = jnp.broadcast_to(jnp.arange(S), (B, S))

    e = frames + sinusoidal_pos(epos, D, cfg.dtype)
    e = cs(e, ctx, "B", "M", None)

    def enc_step(x, lp):
        ap, mp = lp["attn"], lp["mlp"]
        y, _ = attn_forward(rmsnorm(x, ap["ln"], cfg.norm_eps), ap, cfg, ctx,
                            epos, causal=False)
        x = x + y
        x = x + mlp_forward(rmsnorm(x, mp["ln"], cfg.norm_eps), mp, cfg)
        return cs(x, ctx, "B", "M", None), None

    estep = jax.checkpoint(enc_step) if cfg.remat else enc_step
    e, _ = scan_or_unroll(estep, e, params["enc_layers"],
                          scan=cfg.scan_layers)
    e = rmsnorm(e, params["enc_norm"], cfg.norm_eps)

    d = embed_tokens(params, tokens, cfg, ctx)
    d = d + sinusoidal_pos(dpos, D, cfg.dtype)

    def dec_step(x, lp):
        ap, xp, mp = lp["attn"], lp["xattn"], lp["mlp"]
        y, skv = attn_forward(rmsnorm(x, ap["ln"], cfg.norm_eps), ap, cfg,
                              ctx, dpos, causal=True, collect_kv=collect_kv)
        x = x + y
        y, xkv = attn_forward(rmsnorm(x, xp["ln"], cfg.norm_eps), xp, cfg,
                              ctx, dpos, causal=False, kv_src=e,
                              kv_positions=epos, collect_kv=collect_kv)
        x = x + y
        x = x + mlp_forward(rmsnorm(x, mp["ln"], cfg.norm_eps), mp, cfg)
        return cs(x, ctx, "B", "M", None), (skv, xkv)

    dstep = jax.checkpoint(dec_step) if cfg.remat else dec_step
    d, kvs = scan_or_unroll(dstep, d, params["dec_layers"],
                            scan=cfg.scan_layers)
    d = rmsnorm(d, params["final_norm"], cfg.norm_eps)
    return d, jnp.zeros((), F32), {"layers": kvs}


# ---------------------------------------------------------------------------
# public train loss
# ---------------------------------------------------------------------------
def loss_fn(params, batch, cfg: ModelConfig, ctx: MeshCtx,
            aux_weight: float = 0.01):
    fwd = forward_encdec if cfg.is_encoder_decoder else forward_lm
    h, aux, _ = fwd(params, batch, cfg, ctx)
    labels, mask = batch["labels"], batch["mask"].astype(F32)
    if cfg.n_patches:   # loss only over text positions; pad label block
        pad = jnp.zeros((labels.shape[0], cfg.n_patches), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        mask = jnp.concatenate([jnp.zeros_like(pad, F32), mask], axis=1)
    loss = xent_loss(h, params, labels, mask, cfg, ctx)
    return loss + aux_weight * aux, {"xent": loss, "aux": aux}
