"""KV-cache structures, prefill and single-token decode for every family.

Cache layouts (leading dim = stacked layers, scanned):
  gqa    : k/v (L, B, Sc, KV, hd), Sc = sliding_window or max_seq (ring)
  mla    : ckv (L, B, S, R) + kr (L, B, S, dr)   — compressed latent cache
  ssm    : state (L, B, H, N, P) f32 + conv (L, B, K-1, conv_dim)
  hybrid : ssm caches + shared-attn k/v (n_attn, B, S, KV, hd)
  encdec : decoder self k/v (L,...) + frozen cross k/v (L, B, F, KV, hd)

Caches are sequence-sharded over the ``model`` axis (flash-decoding): the
attention softmax reductions over the sharded seq dim become all-reduces.
``slot_pos`` maps cache slots to absolute positions (-1 = empty) and makes
ring buffers (sliding window) and partially-filled caches uniform.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    decode_attention, moe_decode, mlp_swiglu, rope, rmsnorm, tree_index,
)
from repro.models.model import (
    F32, cs, embed_tokens, mlp_forward, scan_or_unroll, unembed_matrix,
    forward_lm, forward_encdec,
)
from repro.models.ssm import mamba2_mixer
from repro.sharding.ctx import MeshCtx


def _n_attn(cfg: ModelConfig) -> int:
    """zamba2: number of shared-attn invocations."""
    k = cfg.shared_attn_every
    return (cfg.n_layers + k - 1) // k if k else 0


def cache_len(cfg: ModelConfig, max_seq: int) -> int:
    return min(cfg.sliding_window, max_seq) if cfg.sliding_window else max_seq


def padded_frames(cfg: ModelConfig) -> int:
    """Cross-attn cache length, padded so the seq dim shards (1500->1536)."""
    return (cfg.enc_frames + 255) // 256 * 256


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, B: int, max_seq: int, abstract: bool = False):
    """Zeroed (or ShapeDtypeStruct) cache pytree for ``decode_step``."""
    mk = ((lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract
          else (lambda s, d: jnp.zeros(s, d)))
    # empty cache slots must read as position -1 (invalid)
    mk_slots = ((lambda s: jax.ShapeDtypeStruct(s, jnp.int32)) if abstract
                else (lambda s: jnp.full(s, -1, jnp.int32)))
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    Sc = cache_len(cfg, max_seq)
    c: dict = {"pos": mk((B,), jnp.int32)}
    fam = cfg.family

    if fam in ("ssm", "hybrid"):
        H, N, Pd = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        cd = cfg.d_inner + 2 * N
        c["state"] = mk((L, B, H, N, Pd), F32)
        c["conv"] = mk((L, B, cfg.ssm_conv - 1, cd), cfg.dtype)
        if fam == "hybrid":
            na = _n_attn(cfg)
            c["ak"] = mk((na, B, Sc, KV, hd), cfg.dtype)
            c["av"] = mk((na, B, Sc, KV, hd), cfg.dtype)
            c["slot_pos"] = mk_slots((B, Sc))
        return c

    if cfg.attention == "mla":
        R, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
        n_scan = cfg.n_layers - cfg.first_dense_layers
        c["ckv"] = mk((n_scan, B, Sc, R), cfg.dtype)
        c["kr"] = mk((n_scan, B, Sc, dr), cfg.dtype)
        if cfg.first_dense_layers:
            c["d_ckv"] = mk((cfg.first_dense_layers, B, Sc, R), cfg.dtype)
            c["d_kr"] = mk((cfg.first_dense_layers, B, Sc, dr), cfg.dtype)
        c["slot_pos"] = mk_slots((B, Sc))
        return c

    c["k"] = mk((L, B, Sc, KV, hd), cfg.dtype)
    c["v"] = mk((L, B, Sc, KV, hd), cfg.dtype)
    c["slot_pos"] = mk_slots((B, Sc))
    if cfg.is_encoder_decoder:
        Fp = padded_frames(cfg)
        c["xk"] = mk((L, B, Fp, KV, hd), cfg.dtype)
        c["xv"] = mk((L, B, Fp, KV, hd), cfg.dtype)
    return c


def cache_pspecs(cfg: ModelConfig, ctx: MeshCtx, ba=...):
    """PartitionSpec tree matching init_cache (seq -> model axis).
    ``ba`` overrides the batch axes (None for non-divisible batches)."""
    from jax.sharding import PartitionSpec as P
    m = ctx.model_axis
    if ba is ...:
        ba = ctx.batch_axes
    specs = {"pos": P(ba)}
    fam = cfg.family
    if fam in ("ssm", "hybrid"):
        specs["state"] = P(None, ba, None, None, None)
        specs["conv"] = P(None, ba, None, None)
        if fam == "hybrid":
            specs["ak"] = specs["av"] = P(None, ba, m, None, None)
            specs["slot_pos"] = P(ba, m)
        return specs
    if cfg.attention == "mla":
        specs["ckv"] = P(None, ba, m, None)
        specs["kr"] = P(None, ba, m, None)
        if cfg.first_dense_layers:
            specs["d_ckv"] = P(None, ba, m, None)
            specs["d_kr"] = P(None, ba, m, None)
        specs["slot_pos"] = P(ba, m)
        return specs
    specs["k"] = specs["v"] = P(None, ba, m, None, None)
    specs["slot_pos"] = P(ba, m)
    if cfg.is_encoder_decoder:
        specs["xk"] = specs["xv"] = P(None, ba, m, None, None)
    return specs


# ---------------------------------------------------------------------------
# per-layer decode helpers
# ---------------------------------------------------------------------------
def _gqa_decode(x1, ap, cfg, ctx, kc, vc, slot_pos, pos, slot, *,
                kv_cache_only=False):
    """x1: (B,1,D). kc/vc: (B,Sc,KV,hd). Returns (attn_out, kc, vc)."""
    B = x1.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x1, ap["wq"]).reshape(B, 1, H, hd)
    kn = jnp.einsum("bsd,dh->bsh", x1, ap["wk"]).reshape(B, 1, KV, hd)
    vn = jnp.einsum("bsd,dh->bsh", x1, ap["wv"]).reshape(B, 1, KV, hd)
    if cfg.rope_theta:
        q = rope(q, pos[:, None], cfg.rope_theta)
        kn = rope(kn, pos[:, None], cfg.rope_theta)
    kc = kc.at[jnp.arange(B), slot].set(kn[:, 0])
    vc = vc.at[jnp.arange(B), slot].set(vn[:, 0])
    out = decode_attention(q, kc, vc, slot_pos, pos)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, H * hd), ap["wo"])
    return out, kc, vc


def _cross_decode(x1, ap, cfg, xk, xv, enc_len):
    """Cross-attention over the (padded) frozen encoder cache; slots beyond
    enc_len are masked via slot_pos > pos."""
    B = x1.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x1, ap["wq"]).reshape(B, 1, H, hd)
    slot_pos = jnp.broadcast_to(jnp.arange(xk.shape[1]), (B, xk.shape[1]))
    out = decode_attention(q, xk, xv, slot_pos,
                           jnp.full((B,), enc_len - 1, jnp.int32))
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, H * hd), ap["wo"])


def _mla_decode(x1, ap, cfg, ckv_c, kr_c, slot_pos, pos, slot):
    """Absorbed-form MLA decode. ckv_c: (B,Sc,R); kr_c: (B,Sc,dr)."""
    B = x1.shape[0]
    H = cfg.n_heads
    R, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    q = rmsnorm(jnp.einsum("bsd,dq->bsq", x1, ap["wq_a"]), ap["q_norm"],
                cfg.norm_eps)
    q = jnp.einsum("bsq,qh->bsh", q, ap["wq_b"]).reshape(B, 1, H, dn + dr)
    qn, qr = q[..., :dn], rope(q[..., dn:], pos[:, None], cfg.rope_theta)

    new = jnp.einsum("bsd,dr->bsr", x1, ap["wkv_a"])
    ckv_n = rmsnorm(new[..., :R], ap["kv_norm"], cfg.norm_eps)
    kr_n = rope(new[:, :, None, R:], pos[:, None], cfg.rope_theta)[:, :, 0]
    ckv_c = ckv_c.at[jnp.arange(B), slot].set(ckv_n[:, 0])
    kr_c = kr_c.at[jnp.arange(B), slot].set(kr_n[:, 0])

    wkv_b = ap["wkv_b"].reshape(R, H, dn + dv)
    wk, wv = wkv_b[..., :dn], wkv_b[..., dn:]
    q_lat = jnp.einsum("bhd,rhd->bhr", qn[:, 0].astype(F32),
                       wk.astype(F32))                       # absorb W_uk
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv_c.astype(F32))
         + jnp.einsum("bhd,bsd->bhs", qr[:, 0].astype(F32),
                      kr_c.astype(F32))) / jnp.sqrt(float(dn + dr))
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, ckv_c.astype(F32))
    out = jnp.einsum("bhr,rhd->bhd", o_lat, wv.astype(F32))  # absorb W_uv
    out = out.reshape(B, 1, H * dv).astype(x1.dtype)
    return jnp.einsum("bsh,hd->bsd", out, ap["wo"]), ckv_c, kr_c


# ---------------------------------------------------------------------------
# decode_step (the serve_step lowered for decode_* / long_* shapes)
# ---------------------------------------------------------------------------
def decode_step(params, cache, tokens, cfg: ModelConfig, ctx: MeshCtx):
    """One new token per sequence. tokens: (B,). Returns (logits, cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]                              # (B,)
    x = embed_tokens(params, tokens[:, None], cfg, None)
    fam = cfg.family
    cache = dict(cache)

    if fam in ("ssm", "hybrid"):
        if fam == "hybrid":
            Sc = cache["ak"].shape[2]
            slot = pos % Sc
            slot_pos = cache["slot_pos"].at[jnp.arange(B), slot].set(pos)
            cache["slot_pos"] = slot_pos
            ak_all, av_all = cache["ak"], cache["av"]

        def step(carry, xs):
            x1, ak, av = carry
            i, lp, st, cv = xs
            if fam == "hybrid" and cfg.shared_attn_every:
                static = isinstance(i, int)
                def with_attn(op):
                    x1, ak, av = op
                    ai = i // cfg.shared_attn_every
                    bp = params["shared_block"]["attn"]
                    y, k2, v2 = _gqa_decode(
                        rmsnorm(x1, bp["ln"], cfg.norm_eps), bp, cfg, ctx,
                        ak[ai], av[ai], slot_pos, pos, slot)
                    x1 = x1 + y
                    mp = params["shared_block"]["mlp"]
                    x1 = x1 + mlp_forward(
                        rmsnorm(x1, mp["ln"], cfg.norm_eps), mp, cfg)
                    ak = jax.lax.dynamic_update_index_in_dim(ak, k2, ai, 0)
                    av = jax.lax.dynamic_update_index_in_dim(av, v2, ai, 0)
                    return x1, ak, av
                if static:
                    if i % cfg.shared_attn_every == 0:
                        x1, ak, av = with_attn((x1, ak, av))
                else:
                    x1, ak, av = jax.lax.cond(
                        i % cfg.shared_attn_every == 0, with_attn,
                        lambda op: op, (x1, ak, av))
            mp = lp["mamba"]
            h = rmsnorm(x1, mp["ln"], cfg.norm_eps)
            y, (st, cv) = mamba2_mixer(h, mp, cfg, ctx, state=st,
                                       conv_state=cv, decode=True)
            return (x1 + y, ak, av), (st, cv)

        na = _n_attn(cfg)
        dummy = (jnp.zeros((max(na, 1), B, 1, 1, 1), cfg.dtype),) * 2
        carry0 = (x, cache.get("ak", dummy[0]), cache.get("av", dummy[1]))
        if cfg.scan_layers:
            (x, ak, av), (st, cv) = jax.lax.scan(
                step, carry0,
                (jnp.arange(cfg.n_layers), params["layers"], cache["state"],
                 cache["conv"]))
        else:   # unrolled: python layer index -> static shared-attn branch
            carry, ys = carry0, []
            for i in range(cfg.n_layers):
                xs_i = tree_index((params["layers"], cache["state"],
                                   cache["conv"]), i)
                carry, y = step(carry, (i,) + xs_i)
                ys.append(y)
            (x, ak, av) = carry
            st, cv = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
        cache["state"], cache["conv"] = st, cv
        if fam == "hybrid":
            cache["ak"], cache["av"] = ak, av

    elif cfg.attention == "mla":
        Sc = cache["ckv"].shape[2]
        slot = pos % Sc
        slot_pos = cache["slot_pos"].at[jnp.arange(B), slot].set(pos)
        cache["slot_pos"] = slot_pos

        if cfg.first_dense_layers:
            def dstep(x1, xs):
                lp, ckv_l, kr_l = xs
                y, ckv_l, kr_l = _mla_decode(
                    rmsnorm(x1, lp["attn"]["ln"], cfg.norm_eps), lp["attn"],
                    cfg, ckv_l, kr_l, slot_pos, pos, slot)
                x1 = x1 + y
                x1 = x1 + mlp_forward(
                    rmsnorm(x1, lp["mlp"]["ln"], cfg.norm_eps),
                    lp["mlp"], cfg)
                return x1, (ckv_l, kr_l)
            x, (dckv, dkr) = scan_or_unroll(
                dstep, x, (params["dense_layers"], cache["d_ckv"],
                           cache["d_kr"]), scan=cfg.scan_layers)
            cache["d_ckv"], cache["d_kr"] = dckv, dkr

        def step(x1, xs):
            lp, ckv_l, kr_l = xs
            y, ckv_l, kr_l = _mla_decode(
                rmsnorm(x1, lp["attn"]["ln"], cfg.norm_eps), lp["attn"],
                cfg, ckv_l, kr_l, slot_pos, pos, slot)
            x1 = x1 + y
            if "moe" in lp:
                hn = rmsnorm(x1, lp["moe"]["ln"], cfg.norm_eps)
                y2, _ = moe_decode(hn, lp["moe"], cfg, ctx)
                if cfg.n_shared_experts:
                    y2 = y2 + mlp_swiglu(hn, lp["moe"]["sh_wg"],
                                         lp["moe"]["sh_wu"],
                                         lp["moe"]["sh_wd"])
                x1 = x1 + y2
            else:
                x1 = x1 + mlp_forward(
                    rmsnorm(x1, lp["mlp"]["ln"], cfg.norm_eps),
                    lp["mlp"], cfg)
            return x1, (ckv_l, kr_l)

        x, (ckv, kr) = scan_or_unroll(
            step, x, (params["layers"], cache["ckv"], cache["kr"]),
            scan=cfg.scan_layers)
        cache["ckv"], cache["kr"] = ckv, kr

    else:  # gqa families (dense / moe / vlm / encdec)
        Sc = cache["k"].shape[2]
        slot = pos % Sc
        slot_pos = cache["slot_pos"].at[jnp.arange(B), slot].set(pos)
        cache["slot_pos"] = slot_pos

        def step(x1, xs):
            lp, kc, vc, *xkv = xs
            ap = lp["attn"]
            y, kc, vc = _gqa_decode(rmsnorm(x1, ap["ln"], cfg.norm_eps), ap,
                                    cfg, ctx, kc, vc, slot_pos, pos, slot)
            x1 = x1 + y
            if cfg.is_encoder_decoder:
                xp = lp["xattn"]
                x1 = x1 + _cross_decode(rmsnorm(x1, xp["ln"], cfg.norm_eps),
                                        xp, cfg, xkv[0], xkv[1],
                                        cfg.enc_frames)
            if "moe" in lp:
                hn = rmsnorm(x1, lp["moe"]["ln"], cfg.norm_eps)
                y2, _ = moe_decode(hn, lp["moe"], cfg, ctx)
                x1 = x1 + y2
            else:
                x1 = x1 + mlp_forward(
                    rmsnorm(x1, lp["mlp"]["ln"], cfg.norm_eps),
                    lp["mlp"], cfg)
            return x1, (kc, vc)

        layer_p = (params["dec_layers"] if cfg.is_encoder_decoder
                   else params["layers"])
        xs = ((layer_p, cache["k"], cache["v"], cache["xk"], cache["xv"])
              if cfg.is_encoder_decoder
              else (layer_p, cache["k"], cache["v"]))
        x, (k, v) = scan_or_unroll(step, x, xs, scan=cfg.scan_layers)
        cache["k"], cache["v"] = k, v

    cache["pos"] = pos + 1
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, unembed_matrix(params, cfg))
    logits = cs(logits[:, 0], ctx, "B", "M")
    return logits, cache


# ---------------------------------------------------------------------------
# prefill_step (the serve_step lowered for prefill_* shapes)
# ---------------------------------------------------------------------------
def prefill_step(params, batch, cfg: ModelConfig, ctx: MeshCtx,
                 last_index=None):
    """Full-sequence prefill: returns (last-token logits, populated cache).

    ``last_index`` (int32 (B,), optional) selects which position's hidden
    state feeds the logits — the last *real* token when the batch is
    right-padded to a block boundary (paged serving, SERVING.md §3).
    Right padding never perturbs earlier positions (causal attention), so
    the default ``h[:, -1]`` remains exact for unpadded prompts."""
    fwd = forward_encdec if cfg.is_encoder_decoder else forward_lm
    h, _, kvs = fwd(params, batch, cfg, ctx, collect_kv=True)
    B, S, _ = h.shape
    h_last = (h[:, -1] if last_index is None
              else h[jnp.arange(B), last_index])
    logits = jnp.einsum("bd,dv->bv", h_last, unembed_matrix(params, cfg))
    logits = cs(logits, ctx, "B", "M")

    cache = init_cache(cfg, B, S)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    fam = cfg.family

    if fam in ("ssm", "hybrid"):
        state, conv = kvs["layers"]
        cache["state"], cache["conv"] = state, conv
        # hybrid shared-attn kv is recomputed at decode start (documented)
        return logits, cache

    def ring(t):  # (L,B,S,KV,hd)->(L,B,Sc,·) ring layout for sliding window
        Sc = cache_len(cfg, S)
        if Sc == S:
            return t
        shift = (S - Sc) % Sc
        return jnp.roll(t[:, :, -Sc:], shift, axis=2)

    Sc = cache_len(cfg, S)
    if Sc == S:
        slot_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    else:
        shift = (S - Sc) % Sc
        slot_pos = jnp.broadcast_to(
            S - Sc + (jnp.arange(Sc) - shift) % Sc, (B, Sc))
    cache["slot_pos"] = slot_pos.astype(jnp.int32)

    if cfg.attention == "mla":
        assert Sc == S, "MLA archs have no sliding window"
        ckv, kr = kvs["layers"]
        cache["ckv"], cache["kr"] = ckv, kr
        if kvs.get("dense") is not None:
            dckv, dkr = kvs["dense"]
            cache["d_ckv"], cache["d_kr"] = dckv, dkr
        return logits, cache

    if cfg.is_encoder_decoder:
        (sk, sv), (xk, xv) = kvs["layers"]
        cache["k"], cache["v"] = sk, sv
        Fp = padded_frames(cfg)
        pad = Fp - xk.shape[2]
        cache["xk"] = jnp.pad(xk, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["xv"] = jnp.pad(xv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return logits, cache

    k, v = kvs["layers"]
    cache["k"], cache["v"] = ring(k), ring(v)
    return logits, cache


# ---------------------------------------------------------------------------
# paged decode (serving, SERVING.md §3): gather-by-block-table
# ---------------------------------------------------------------------------
def paged_supported(cfg: ModelConfig, max_seq: int) -> bool:
    """The paged path covers the plain-GQA text families (dense/moe) with
    a non-ring cache; MLA/SSM/hybrid/encdec/vlm and sliding-window rings
    use the dense slot fallback (support matrix in SERVING.md §3)."""
    return (cfg.attention == "gqa" and cfg.family not in ("ssm", "hybrid")
            and not cfg.is_encoder_decoder and not cfg.n_patches
            and cache_len(cfg, max_seq) == max_seq)


def cache_to_blocks(cache: dict, block: int):
    """Chop a B=1 prefill cache into KV blocks: (L,1,S,KV,hd) k/v ->
    (S//block, L, block, KV, hd), ready to scatter into the pools by
    block id. S must be a block multiple (right-pad the prompt)."""
    k, v = cache["k"], cache["v"]
    L, _, S, KV, hd = k.shape
    assert S % block == 0, (S, block)

    def chop(t):
        t = t[:, 0].reshape(L, S // block, block, KV, hd)
        return t.transpose(1, 0, 2, 3, 4)
    return chop(k), chop(v)


def paged_decode_step(params, k_pool, v_pool, table, pos, tokens,
                      cfg: ModelConfig, ctx: MeshCtx):
    """One decode token per sequence against paged KV pools.

    k_pool/v_pool: (P, L, block, KV, hd) — the pool, indexed by block id.
    table:         (B, nb) int32 — per-slot block tables (id 0 = the null
                   block for unused entries / empty slots).
    pos:           (B,) int32 absolute position of the incoming token.
    tokens:        (B,) int32.

    Semantics are identical to ``decode_step`` on the dense cache the
    table describes: the pools are gathered to (L, B, nb*block, KV, hd),
    ``slot_pos`` is reconstructed from ``pos`` (slot i holds position i —
    no ring, enforced by ``paged_supported``), and after the step only
    the block containing the written slot is scattered back. Empty slots
    point at the null block, which absorbs their garbage writes.
    Returns (logits, k_pool, v_pool).
    """
    B, nb = table.shape
    P, L, block, KV, hd = k_pool.shape
    Sc = nb * block

    def gather(pool):
        t = pool[table]                          # (B, nb, L, block, KV, hd)
        return t.transpose(2, 0, 1, 3, 4, 5).reshape(L, B, Sc, KV, hd)

    iota = jnp.arange(Sc, dtype=jnp.int32)
    slot_pos = jnp.where(iota[None, :] < pos[:, None], iota[None, :], -1)
    cache = {"pos": pos, "slot_pos": slot_pos,
             "k": gather(k_pool), "v": gather(v_pool)}
    logits, cache = decode_step(params, cache, tokens, cfg, ctx)

    bi = pos // block                            # block just written, per row

    def cut(row, b):                             # row: (L, Sc, KV, hd)
        return jax.lax.dynamic_slice_in_dim(row, b * block, block, axis=1)
    ids = table[jnp.arange(B), bi]
    k_pool = k_pool.at[ids].set(jax.vmap(cut, in_axes=(1, 0))(cache["k"], bi))
    v_pool = v_pool.at[ids].set(jax.vmap(cut, in_axes=(1, 0))(cache["v"], bi))
    return logits, k_pool, v_pool
