"""Mamba-2 (SSD — state-space duality) block, pure-jnp reference path.

The chunked SSD algorithm follows arXiv:2405.21060: intra-chunk attention-
like term + inter-chunk state recurrence (``lax.scan`` over chunks). The
Pallas kernel in ``repro.kernels.ssd`` implements the same contract and is
validated against ``ssd_chunked`` (this file is the oracle).

Single group (G=1) for B/C projections; per-head decays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import scan_or_unroll
from repro.sharding.ctx import constrain as cs

F32 = jnp.float32


def ssd_chunked(x, dt, a_log, bmat, cmat, chunk: int, state0=None,
                unroll: bool = False, remat_groups: int = 4):
    """Chunked SSD scan.

    x:    (B, S, H, P)   inputs per head
    dt:   (B, S, H)      softplus'd step sizes
    a_log:(H,)           A = -exp(a_log)
    bmat: (B, S, N)      input->state projection (G=1, shared over heads)
    cmat: (B, S, N)      state->output projection
    Returns y (B, S, H, P), final_state (B, H, N, P).
    """
    B, S, H, Pdim = x.shape
    N = bmat.shape[-1]
    Q = min(chunk, S)
    if unroll:                       # cap the unrolled body count at 16
        Q = max(Q, (S + 15) // 16)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    A = -jnp.exp(a_log.astype(F32))                      # (H,) negative
    dt = dt.astype(F32)
    loga = dt * A[None, None, :]                          # (B,S,H) log-decay
    bx = x.astype(F32) * dt[..., None]                    # dt-scaled input

    # chunked views, chunk-major for scan
    def ck(t, shape):
        return t.reshape((B, nc) + shape).transpose((1, 0) + tuple(range(2, 2 + len(shape))))

    loga_c = ck(loga, (Q, H))                             # (nc,B,Q,H)
    bx_c = ck(bx, (Q, H, Pdim))
    b_c = ck(bmat.astype(F32), (Q, N))
    c_c = ck(cmat.astype(F32), (Q, N))

    if state0 is None:
        state0 = jnp.zeros((B, H, N, Pdim), F32)

    def body(state, xs):
        la, bxq, bq, cq = xs                              # per-chunk blocks
        cum = jnp.cumsum(la, axis=1)                      # (B,Q,H) inclusive
        total = cum[:, -1:, :]                            # (B,1,H)
        # intra-chunk: masked (C_i . B_j) * exp(cum_i - cum_j), j <= i
        cb = jnp.einsum("bin,bjn->bij", cq, bq)           # (B,Q,Q)
        seg = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,H) i-j
        iota = jnp.arange(Q)
        causal = (iota[:, None] >= iota[None, :])[None, :, :, None]
        m = jnp.where(causal, jnp.exp(seg), 0.0) * cb[..., None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, bxq)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cum)                           # (B,Q,H)
        y_inter = jnp.einsum("bin,bhnp,bih->bihp", cq, state, decay_in)
        # state update: decay whole chunk + inject chunk inputs
        decay_out = jnp.exp(total - cum)                  # (B,Q,H)
        inj = jnp.einsum("bjn,bjhp,bjh->bhnp", bq, bxq, decay_out)
        state = state * jnp.exp(total).transpose(0, 2, 1)[..., None] + inj
        return state, y_intra + y_inter

    xs = (loga_c, bx_c, b_c, c_c)
    if unroll or remat_groups <= 1 or nc % remat_groups or nc == remat_groups:
        state, y_c = scan_or_unroll(body, state0, xs, scan=not unroll)
    else:
        # nested remat (perf iteration zamba2/H2): save only every
        # (nc/remat_groups)-th inter-chunk state for backward; the inner
        # chunks recompute — peak bwd memory drops ~(nc/groups)x.
        g = remat_groups
        per = nc // g
        xs_g = jax.tree.map(
            lambda a: a.reshape((g, per) + a.shape[1:]), xs)

        @jax.checkpoint
        def group_body(state, xs_one):
            return jax.lax.scan(body, state, xs_one)

        state, y_g = jax.lax.scan(group_body, state0, xs_g)
        y_c = y_g.reshape((nc,) + y_g.shape[2:])
    y = y_c.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Pdim)
    return y.astype(x.dtype), state


def ssd_decode_step(x, dt, a_log, bmat, cmat, state):
    """Single-token SSD update. x: (B,H,P); dt: (B,H); b/c: (B,N);
    state: (B,H,N,P) -> y (B,H,P), new state."""
    A = -jnp.exp(a_log.astype(F32))
    a = jnp.exp(dt.astype(F32) * A[None, :])              # (B,H)
    bx = x.astype(F32) * dt.astype(F32)[..., None]        # (B,H,P)
    state = state * a[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", bmat.astype(F32), bx)
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(F32), state)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# full mamba2 mixer block
# ---------------------------------------------------------------------------
def causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, C); w: (K, C); b: (C,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def mamba2_mixer(x, p, cfg, ctx=None, *, state=None, conv_state=None,
                 decode=False):
    """x: (B, S, D) (S=1 for decode). Returns (y, (ssm_state, conv_state)).

    p: wzx (D, 2*di), wbcdt (D, 2N+H), conv_xw/conv_bcw split depthwise
       convs, a_log (H,), dt_bias (H,), d_skip (H,), norm_w (di,),
       out_proj (di, D). z/x are head-sharded; B/C/dt replicated.
    """
    B, S, D = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.ssm_conv

    # head-parallel SSD (perf iteration zamba2/H1', EXPERIMENTS §Perf):
    # the SSD recurrence is sequential over seq, so seq-sharded operands
    # would make GSPMD snake the scan across devices (collective-permute
    # per chunk). Constrain the mixer internals to seq-replicated /
    # head-sharded — heads are independent, so the scan is local.
    zx = jnp.einsum("bsd,de->bse", x, p["wzx"])          # (B,S,2di)
    zx = cs(zx, ctx, "B", None, "M")
    bcdt = jnp.einsum("bsd,de->bse", x, p["wbcdt"])      # (B,S,2N+H) repl.
    bcdt = cs(bcdt, ctx, "B", None, None)
    z, xs_r = jnp.split(zx, [di], axis=-1)
    bc, dt = jnp.split(bcdt, [2 * N], axis=-1)

    if decode:
        # roll conv state, apply conv at the single new position
        cx, cbc = jnp.split(conv_state, [di], axis=-1)
        fx = jnp.concatenate([cx, xs_r], axis=1)             # (B, K, di)
        fbc = jnp.concatenate([cbc, bc], axis=1)             # (B, K, 2N)
        xs_c = (fx * p["conv_xw"][None]).sum(1, keepdims=True) + p["conv_xb"]
        bc_c = (fbc * p["conv_bcw"][None]).sum(1, keepdims=True) + p["conv_bcb"]
        conv_state = jnp.concatenate([fx[:, 1:], fbc[:, 1:]], axis=-1)
    else:
        xs_c = causal_conv(xs_r, p["conv_xw"], p["conv_xb"])
        bc_c = causal_conv(bc, p["conv_bcw"], p["conv_bcb"])
        # decode-handoff conv state = last K-1 raw inputs (pad if S < K-1)
        tail = jnp.concatenate([xs_r, bc], axis=-1)
        conv_state = jnp.pad(tail[:, -(K - 1):, :],
                             ((0, 0), (max(0, K - 1 - S), 0), (0, 0)))
    xs_c = jax.nn.silu(xs_c.astype(F32)).astype(x.dtype)
    bc_c = jax.nn.silu(bc_c.astype(F32)).astype(x.dtype)
    xs, bmat, cmat = xs_c, bc_c[..., :N], bc_c[..., N:]
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))

    xh = xs.reshape(B, S, H, Pd)
    if decode:
        y, state = ssd_decode_step(xh[:, 0], dt[:, 0], p["a_log"],
                                   bmat[:, 0], cmat[:, 0], state)
        y = y[:, None]
    else:
        y, state = ssd_chunked(xh, dt, p["a_log"], bmat, cmat,
                               cfg.ssm_chunk, state0=state,
                               unroll=not cfg.scan_layers)
    y = y + xh.astype(y.dtype) * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)

    # gated rmsnorm + output projection (norm reduces over the sharded di
    # dim -> one small all-reduce; the out_proj partial-sums over di)
    g = jax.nn.silu(z.astype(F32))
    h = y.astype(F32) * g
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + cfg.norm_eps)
    h = (h * p["norm_w"].astype(F32)).astype(x.dtype)
    h = cs(h, ctx, "B", None, "M")
    out = jnp.einsum("bse,ed->bsd", h, p["out_proj"])
    return out, (state, conv_state)
