"""Per-architecture parameter spec trees (see ``repro.models.params``).

Layout decisions (see DESIGN.md §4):
* weights are FSDP-sharded: logical axis "embed" (or the largest dim) maps
  to the ``data`` mesh axis; optimizer states inherit it (ZeRO-1).
* MoE expert weights use the *physical* EP(+TP) layout
  ``(M, E_loc, D, F_t)`` where M = model-axis size, ``F_t = F / tpi``
  (pure relayout of the logical ``(E, D, F)``; see layers.moe_topology).
* vocab maps to ``model`` so the chunked cross-entropy reduces over a
  model-axis all-reduce.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.layers import moe_topology
from repro.models.params import Param, stack_specs


# ---------------------------------------------------------------------------
# block param specs
# ---------------------------------------------------------------------------
def attn_specs(cfg: ModelConfig, prefix_norm: bool = True):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq": Param((D, H * hd), ("embed", "heads")),
        "wk": Param((D, KV * hd), ("embed", "kv_heads")),
        "wv": Param((D, KV * hd), ("embed", "kv_heads")),
        "wo": Param((H * hd, D), ("heads", "embed")),
    }
    if prefix_norm:
        s["ln"] = Param((D,), (None,), "ones")
    return s


def mla_specs(cfg: ModelConfig):
    D, H = cfg.d_model, cfg.n_heads
    R, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "ln": Param((D,), (None,), "ones"),
        "wq_a": Param((D, qr), ("embed", None)),
        "q_norm": Param((qr,), (None,), "ones"),
        "wq_b": Param((qr, H * (dn + dr)), (None, "heads")),
        "wkv_a": Param((D, R + dr), ("embed", None)),
        "kv_norm": Param((R,), (None,), "ones"),
        "wkv_b": Param((R, H * (dn + dv)), (None, "heads")),
        "wo": Param((H * dv, D), ("heads", "embed")),
    }


def mlp_specs(cfg: ModelConfig, d_ff: int):
    D = cfg.d_model
    s = {"ln": Param((D,), (None,), "ones")}
    if cfg.mlp_type == "swiglu":
        s.update(wg=Param((D, d_ff), ("embed", "mlp")),
                 wu=Param((D, d_ff), ("embed", "mlp")),
                 wd=Param((d_ff, D), ("mlp", "embed")))
    else:  # gelu
        s.update(wi=Param((D, d_ff), ("embed", "mlp")),
                 wd=Param((d_ff, D), ("mlp", "embed")))
    return s


def moe_specs(cfg: ModelConfig, model_size: int):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ep, tpi, e_loc = moe_topology(E, model_size)
    M, Ft = ep * tpi, F // tpi
    s = {
        "ln": Param((D,), (None,), "ones"),
        "w_router": Param((D, E), (None, None)),
        "wg": Param((M, e_loc, D, Ft),
                    ("expert_shard", None, "expert_embed", None)),
        "wu": Param((M, e_loc, D, Ft),
                    ("expert_shard", None, "expert_embed", None)),
        "wd": Param((M, e_loc, Ft, D),
                    ("expert_shard", None, None, "expert_embed")),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * F
        s.update(sh_wg=Param((D, Fs), ("embed", "mlp")),
                 sh_wu=Param((D, Fs), ("embed", "mlp")),
                 sh_wd=Param((Fs, D), ("mlp", "embed")))
    return s


def mamba_specs(cfg: ModelConfig):
    """Split projections (perf iteration zamba2/H1, EXPERIMENTS §Perf):
    z/x are head-shardable over the model axis ("mlp"); the small B/C/dt
    projection stays replicated, so the SSD runs head-parallel with no
    per-layer gathers of the mixed concat dim."""
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    return {
        "ln": Param((D,), (None,), "ones"),
        "wzx": Param((D, 2 * di), ("embed", "mlp")),
        "wbcdt": Param((D, 2 * N + H), ("embed", None)),
        "conv_xw": Param((K, di), (None, "mlp")),
        "conv_xb": Param((di,), ("mlp",), "zeros"),
        "conv_bcw": Param((K, 2 * N), (None, None)),
        "conv_bcb": Param((2 * N,), (None,), "zeros"),
        "a_log": Param((H,), (None,), "zeros"),
        "dt_bias": Param((H,), (None,), "zeros"),
        "d_skip": Param((H,), (None,), "ones"),
        "norm_w": Param((di,), (None,), "ones"),
        "out_proj": Param((di, D), ("mlp", "embed")),
    }


# ---------------------------------------------------------------------------
# full-model spec trees
# ---------------------------------------------------------------------------
def _decoder_layer_specs(cfg: ModelConfig, model_size: int):
    """One (repeated/scanned) decoder layer for the LM families."""
    if cfg.family == "ssm":
        return {"mamba": mamba_specs(cfg)}
    if cfg.family == "hybrid":
        return {"mamba": mamba_specs(cfg)}          # shared attn lives top-level
    layer = {}
    if cfg.attention == "mla":
        layer["attn"] = mla_specs(cfg)
    else:
        layer["attn"] = attn_specs(cfg)
    if cfg.n_experts:
        layer["moe"] = moe_specs(cfg, model_size)
    else:
        layer["mlp"] = mlp_specs(cfg, cfg.d_ff)
    return layer


def param_specs(cfg: ModelConfig, model_size: int = 1):
    D, V = cfg.d_model, cfg.padded_vocab
    top = {
        "embed": Param((V, D), ("vocab", "embed"), "embed"),
        "final_norm": Param((D,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        top["unembed"] = Param((D, V), ("embed", "vocab"))

    if cfg.is_encoder_decoder:
        enc_layer = {"attn": attn_specs(cfg), "mlp": mlp_specs(cfg, cfg.d_ff)}
        dec_layer = {"attn": attn_specs(cfg),
                     "xattn": attn_specs(cfg),
                     "mlp": mlp_specs(cfg, cfg.d_ff)}
        top["enc_layers"] = stack_specs(enc_layer, cfg.n_enc_layers)
        top["enc_norm"] = Param((D,), (None,), "ones")
        top["dec_layers"] = stack_specs(dec_layer, cfg.n_layers)
        return top

    n_scan = cfg.n_layers - cfg.first_dense_layers
    top["layers"] = stack_specs(_decoder_layer_specs(cfg, model_size), n_scan)
    if cfg.first_dense_layers:     # deepseek-v2: leading dense layer(s)
        dense = {"attn": (mla_specs(cfg) if cfg.attention == "mla"
                          else attn_specs(cfg)),
                 "mlp": mlp_specs(cfg, cfg.first_dense_d_ff or cfg.d_ff)}
        top["dense_layers"] = stack_specs(dense, cfg.first_dense_layers)
    if cfg.shared_attn_every:       # zamba2: one shared attn+mlp block
        top["shared_block"] = {"attn": attn_specs(cfg),
                               "mlp": mlp_specs(cfg, cfg.d_ff)}
    return top
