"""Unified model configuration for the architecture zoo.

Every assigned architecture is expressed as a ``ModelConfig``. The config is
the single source of truth used by:

* ``repro.models``      — parameter specs + forward pass
* ``repro.sharding``    — logical-axis -> mesh-axis rules
* ``repro.launch``      — input_specs / dryrun / train / serve

Shapes follow the assignment sheet verbatim (see DESIGN.md §5 for skips).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    # ---- identity -------------------------------------------------------
    name: str = "model"
    family: str = "dense"            # dense|moe|encdec|ssm|hybrid|vlm|audio
    # ---- trunk ----------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    mlp_type: str = "swiglu"         # swiglu|gelu|none
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    # ---- attention ------------------------------------------------------
    attention: str = "gqa"           # gqa|mla|none
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 -> full attention
    # ---- MLA (deepseek-v2) ----------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # ---- MoE -------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                # expert intermediate size (routed)
    first_dense_layers: int = 0      # leading dense layers (deepseek-v2: 1)
    first_dense_d_ff: int = 0
    # ---- SSM (mamba2 SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256             # SSD chunk length
    # ---- hybrid (zamba2) ---------------------------------------------------
    shared_attn_every: int = 0       # shared attention block cadence (0 = off)
    # ---- encoder-decoder (whisper) ----------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500           # stub audio frontend output length
    # ---- vlm stub ---------------------------------------------------------
    n_patches: int = 0               # stub vision frontend patches (prefix)
    # ---- numerics / training ----------------------------------------------
    remat: bool = True
    scan_layers: bool = True

    # ---- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embed/unembed shard over any mesh."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none" and self.shared_attn_every == 0

    @property
    def supports_long_context(self) -> bool:
        """True when decode at 500k+ context is sub-quadratic / O(window)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding + trunk), for 6ND roofline."""
        from repro.models.model import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_active_params
        return count_active_params(self)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to every LM-family architecture (the 4 shapes).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train|prefill|decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode is O(seq^2); skipped per DESIGN.md §5"
    return True, ""


# Reduced configs for CPU smoke tests: same family/topology, tiny dims.
def smoke_config(cfg: ModelConfig) -> ModelConfig:
    kw: dict[str, Any] = {
        "n_layers": min(cfg.n_layers, 4),
        "d_model": 128,
        "n_heads": 4,
        "n_kv_heads": min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        "head_dim": 32 if cfg.head_dim else 0,
        "d_ff": 256 if cfg.d_ff else 0,
        "vocab_size": 512,
        "enc_frames": 32,
        "n_patches": min(cfg.n_patches, 8),
        "sliding_window": min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        "scan_layers": cfg.scan_layers,
    }
    if cfg.attention == "mla":
        kw.update(kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=32,
                  qk_rope_dim=16, v_head_dim=32, head_dim=0)
    if cfg.n_experts:
        kw.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  moe_d_ff=128,
                  first_dense_layers=min(cfg.first_dense_layers, 1),
                  first_dense_d_ff=256 if cfg.first_dense_d_ff else 0)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
    if cfg.shared_attn_every:
        kw.update(shared_attn_every=2)
    if cfg.is_encoder_decoder:
        kw.update(n_enc_layers=2)
    return cfg.replace(**kw)
