"""whisper-large-v3 [audio] — enc-dec transformer backbone, conv frontend stub.

32L d_model=1280 20H (kv=20, i.e. MHA) d_ff=5120 vocab=51866.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,              # decoder layers
    n_enc_layers=32,          # encoder layers
    is_encoder_decoder=True,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_type="gelu",
    attention="gqa",
    rope_theta=0.0,           # whisper uses learned/sinusoidal pos, not rope
    enc_frames=1500,          # 30s audio -> 1500 frames (conv frontend stub)
)
