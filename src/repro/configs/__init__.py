"""Architecture registry: ``--arch <id>`` resolves through ``get_config``."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    ModelConfig, ShapeConfig, SHAPES, shape_applicable, smoke_config,
)

_ARCH_MODULES = {
    "whisper-large-v3":      "repro.configs.whisper_large_v3",
    "mixtral-8x7b":          "repro.configs.mixtral_8x7b",
    "deepseek-v2-236b":      "repro.configs.deepseek_v2_236b",
    "minitron-4b":           "repro.configs.minitron_4b",
    "granite-3-2b":          "repro.configs.granite_3_2b",
    "starcoder2-3b":         "repro.configs.starcoder2_3b",
    "starcoder2-7b":         "repro.configs.starcoder2_7b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "zamba2-2.7b":           "repro.configs.zamba2_2_7b",
    "mamba2-130m":           "repro.configs.mamba2_130m",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "ARCHS",
    "get_config", "all_configs", "shape_applicable", "smoke_config",
]
