"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
A single *shared* attention+MLP block (one weight set) is applied every 6
mamba blocks (Zamba2's shared-block design).
[arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,              # mamba2 blocks
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,               # shared block MLP width
    vocab_size=32000,
    mlp_type="swiglu",
    attention="gqa",          # attention type of the shared block
    rope_theta=10_000.0,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,
)
