"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff=1536(expert) vocab=102400, MoE 160e top-6.
[arXiv:2405.04434; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,           # MLA: logical kv heads == heads (cache is latent)
    d_ff=12288,               # dense layer-0 FFN width
    vocab_size=102400,
    mlp_type="swiglu",
    attention="mla",
    rope_theta=10_000.0,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1536,
    first_dense_layers=1,
    first_dense_d_ff=12288,
)
