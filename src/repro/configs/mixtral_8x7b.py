"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2, SWA.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,               # kept for reference; experts use moe_d_ff
    vocab_size=32000,
    mlp_type="swiglu",
    attention="gqa",
    rope_theta=1e6,
    sliding_window=4096,      # SWA -> long_500k decode is O(window)
    n_experts=8,
    top_k=2,
    moe_d_ff=14336,
)
