"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints are mesh-agnostic (logical-axes metadata travels in the spec
system, not the files), so rescaling is: build the new mesh, re-derive
shardings from the logical axes, and ``restore_checkpoint`` with the new
shardings — each host loads only the shards it owns (here: device_put of
full arrays; a multi-host deployment plugs per-shard reads into the same
interface).

MoE caveat (DESIGN.md §4): expert weights are stored in the *physical*
EP(+TP) layout (M, e_loc, D, F/tpi), which depends on the model-axis size.
``relayout_moe`` converts between physical layouts through the logical
(E, D, F) form; it is applied automatically when the model-axis size
changes between save and restore.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.models.layers import moe_topology


def relayout_moe(w: np.ndarray, n_experts: int, m_from: int, m_to: int,
                 down_proj: bool) -> np.ndarray:
    """(M1, e_loc1, A, B1) -> (M2, e_loc2, A, B2) through logical (E, A, F).

    For wg/wu the split dim is the last (F); for wd (down_proj) the split
    dim is axis 2."""
    ep1, tpi1, el1 = moe_topology(n_experts, m_from)
    ep2, tpi2, el2 = moe_topology(n_experts, m_to)
    M1, e_loc1 = w.shape[0], w.shape[1]
    assert (M1, e_loc1) == (ep1 * tpi1, el1)

    if down_proj:
        # (M1, el1, Ft1, D): logical (E, F, D)
        Ft1, D = w.shape[2], w.shape[3]
        F = Ft1 * tpi1
        # physical -> logical: m = g*tpi1 + h holds expert g*el1+slot,
        # F rows [h*Ft1:(h+1)*Ft1]
        logical = np.zeros((n_experts, F, D), w.dtype)
        for m in range(M1):
            g, h = divmod(m, tpi1)
            for s in range(el1):
                logical[g * el1 + s, h * Ft1:(h + 1) * Ft1] = w[m, s]
        Ft2 = F // tpi2
        out = np.zeros((ep2 * tpi2, el2, Ft2, D), w.dtype)
        for m in range(ep2 * tpi2):
            g, h = divmod(m, tpi2)
            for s in range(el2):
                out[m, s] = logical[g * el2 + s, h * Ft2:(h + 1) * Ft2]
        return out

    # (M1, el1, D, Ft1): logical (E, D, F)
    D, Ft1 = w.shape[2], w.shape[3]
    F = Ft1 * tpi1
    logical = np.zeros((n_experts, D, F), w.dtype)
    for m in range(M1):
        g, h = divmod(m, tpi1)
        for s in range(el1):
            logical[g * el1 + s, :, h * Ft1:(h + 1) * Ft1] = w[m, s]
    Ft2 = F // tpi2
    out = np.zeros((ep2 * tpi2, el2, D, Ft2), w.dtype)
    for m in range(ep2 * tpi2):
        g, h = divmod(m, tpi2)
        for s in range(el2):
            out[m, s] = logical[g * el2 + s, :, h * Ft2:(h + 1) * Ft2]
    return out


def rescale_state(state_np, cfg, m_from: int, m_to: int):
    """Relayout every MoE leaf of a host-side state pytree for a new
    model-axis size (no-op for dense archs or unchanged meshes)."""
    if m_from == m_to or not cfg.n_experts:
        return state_np

    def visit(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        if any(k in ("wg", "wu", "wd") for k in keys) and "moe" in str(keys):
            down = "wd" in keys
            stacked = leaf.ndim == 5          # scanned layer stack
            if stacked:
                return np.stack([
                    relayout_moe(leaf[i], cfg.n_experts, m_from, m_to, down)
                    for i in range(leaf.shape[0])])
            return relayout_moe(leaf, cfg.n_experts, m_from, m_to, down)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, state_np)
