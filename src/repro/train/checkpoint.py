"""Mesh-agnostic, fault-tolerant checkpointing.

Design (1000+ node posture, DESIGN.md §8):
* **Layout**: one ``.npz``-style blob per pytree leaf (saved via numpy,
  no pickle), plus a JSON manifest carrying the treedef paths, dtypes,
  shapes, logical axes and the training step. Checkpoints are
  *mesh-agnostic*: shardings are re-derived from logical axes on load, so
  restarts may change topology (elastic re-scale).
* **Atomicity**: writes go to ``<dir>/step_N.tmp`` and are committed with a
  single ``rename`` — a crash never leaves a half-readable checkpoint.
* **Async double-buffering**: ``AsyncCheckpointer`` snapshots to host
  (device_get) on the caller thread — the cheap part — then serializes on a
  background writer thread; training continues. The writer pool is
  synchronized with the *Reciprocating runtime lock* (the paper's algorithm
  guarding its own framework's checkpoint path).
* **Retention**: keep the last K checkpoints; an ``emergency()`` hook saves
  immediately (e.g. SIGTERM from the cluster scheduler).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import ml_dtypes
import numpy as np

from repro.core.runtime.reciprocating import ReciprocatingLock


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, state, *, keep: int = 3,
                    extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(state)
    manifest = {"step": step, "time": time.time(), "leaves": {},
                "extra": extra or {}}
    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        dt = str(arr.dtype)
        if arr.dtype == ml_dtypes.bfloat16:      # numpy can't save bf16
            arr = arr.view(np.uint16)
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
        manifest["leaves"][key] = {"file": fn, "dtype": dt,
                                   "shape": list(arr.shape)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit

    # retention
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_state,
                       shardings=None):
    """Restore into the structure of ``like_state``; if ``shardings`` is
    given, leaves are device_put with the (possibly *new* mesh's)
    shardings — the elastic-rescale path."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keys = _flatten_with_paths(like_state)
    sh = _flatten_with_paths(shardings) if shardings is not None else {}
    out = {}
    for key in keys:
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]), allow_pickle=False)
        if meta["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        out[key] = (jax.device_put(arr, sh[key]) if key in sh
                    else jax.numpy.asarray(arr))
    # rebuild the pytree in like_state's structure
    flat = jax.tree_util.tree_flatten_with_path(like_state)
    leaves = []
    for pathk, _ in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        leaves.append(out[key])
    return jax.tree_util.tree_unflatten(flat[1], leaves), manifest


class AsyncCheckpointer:
    """Double-buffered background writer, guarded by a Reciprocating lock."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._lock = ReciprocatingLock()
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, state, block: bool = False) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def write():
            with self._lock:               # serialize concurrent writers
                save_checkpoint(self.directory, step, host_state,
                                keep=self.keep)
                self.last_saved = step

        self.wait()                        # double buffering: at most 1
        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def emergency(self, step: int, state) -> None:
        """Synchronous last-gasp save (SIGTERM path)."""
        with self._lock:
            save_checkpoint(self.directory, step, state, keep=self.keep + 1)
