"""AdamW with global-norm clipping and warmup-cosine schedule.

Implemented directly on pytrees (no optax dependency). Moments are fp32 and
inherit the parameters' 2-D FSDP sharding, i.e. optimizer state is fully
sharded across the mesh (ZeRO).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # bf16 params round away sub-0.4%-relative updates; fp32 master weights
    # (sharded like everything else) are the standard fix. Off by default to
    # keep the dry-run memory tables comparable; train_loop enables it for
    # real runs.
    master_fp32: bool = False


def schedule(oc: OptConfig, step):
    step = step.astype(F32)
    warm = step / jnp.maximum(oc.warmup_steps, 1)
    t = (step - oc.warmup_steps) / jnp.maximum(
        oc.total_steps - oc.warmup_steps, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(t, 0.0, 1.0)))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, 0.1 + 0.9 * cos)


def init_opt_state(params, master_fp32: bool = False):
    zeros = lambda p: jnp.zeros(p.shape, F32)
    st = {"mu": jax.tree.map(zeros, params),
          "nu": jax.tree.map(zeros, params),
          "step": jnp.zeros((), jnp.int32)}
    if master_fp32:
        st["master"] = jax.tree.map(lambda p: p.astype(F32), params)
    return st


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, opt, params, oc: OptConfig):
    step = opt["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)
    use_master = "master" in opt

    def upd(g, m, v, p, pm):
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        base = pm if pm is not None else p.astype(F32)
        step_dir = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * base
        new32 = base - lr * step_dir
        return new32.astype(p.dtype), m, v, new32

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt["mu"])
    flat_v = jax.tree.leaves(opt["nu"])
    flat_p = jax.tree.leaves(params)
    flat_pm = (jax.tree.leaves(opt["master"]) if use_master
               else [None] * len(flat_p))
    new = [upd(g, m, v, p, pm) for g, m, v, p, pm in
           zip(flat_g, flat_m, flat_v, flat_p, flat_pm)]
    new_p = jax.tree.unflatten(tdef, [n[0] for n in new])
    new_opt = {"mu": jax.tree.unflatten(tdef, [n[1] for n in new]),
               "nu": jax.tree.unflatten(tdef, [n[2] for n in new]),
               "step": step}
    if use_master:
        new_opt["master"] = jax.tree.unflatten(tdef, [n[3] for n in new])
    return new_p, new_opt, gn
