"""Fault-tolerance machinery: heartbeats, straggler detection, restart
policy. (Single-host simulable; the interfaces are what a 1000-node
deployment wires to its cluster scheduler — see DESIGN.md §8.)

* ``HeartbeatMonitor`` — per-host step heartbeats; hosts whose last beat
  lags the median by more than ``straggler_factor`` x the median step time
  are flagged stragglers; hosts silent for ``dead_after`` are dead =>
  the driver triggers checkpoint-restore-rescale (elastic path).
* ``StepGuard`` — wall-clock watchdog around train steps: a hung collective
  (the most common 1000-node failure mode) trips the timeout and raises,
  letting the runner restart from the last checkpoint instead of wedging.
* ``RestartPolicy`` — exponential backoff with a budget.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field


@dataclass
class HostState:
    last_beat: float = 0.0
    last_step: int = -1
    step_times: list = field(default_factory=list)


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, straggler_factor: float = 2.0,
                 dead_after: float = 60.0):
        self.hosts = {h: HostState() for h in range(n_hosts)}
        self.straggler_factor = straggler_factor
        self.dead_after = dead_after
        self._lock = threading.Lock()

    def beat(self, host: int, step: int, now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            hs = self.hosts[host]
            if hs.last_step >= 0 and step > hs.last_step:
                hs.step_times.append((now - hs.last_beat)
                                     / max(step - hs.last_step, 1))
                hs.step_times = hs.step_times[-32:]
            hs.last_beat, hs.last_step = now, step

    def _median_step_time(self) -> float:
        times = [t for hs in self.hosts.values() for t in hs.step_times]
        if not times:
            return 0.0
        times.sort()
        return times[len(times) // 2]

    def stragglers(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        med = self._median_step_time()
        if med <= 0:
            return []
        out = []
        with self._lock:
            min_step = min(hs.last_step for hs in self.hosts.values())
            for h, hs in self.hosts.items():
                lag = now - hs.last_beat
                if hs.last_step <= min_step and lag > self.straggler_factor * med:
                    out.append(h)
        return out

    def dead(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        with self._lock:
            return [h for h, hs in self.hosts.items()
                    if now - hs.last_beat > self.dead_after]


class StepGuard:
    """Watchdog: ``with StepGuard(timeout):`` raises if the step hangs."""

    class Hang(RuntimeError):
        pass

    def __init__(self, timeout: float):
        self.timeout = timeout
        self._done = threading.Event()
        self._hung = False

    def __enter__(self):
        def watch():
            if not self._done.wait(self.timeout):
                self._hung = True
        self._t = threading.Thread(target=watch, daemon=True)
        self._t.start()
        return self

    def __exit__(self, *exc):
        self._done.set()
        self._t.join(timeout=0.1)
        if self._hung and exc[0] is None:
            raise StepGuard.Hang(f"step exceeded {self.timeout}s")
        return False


@dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_base: float = 1.0
    backoff_cap: float = 60.0
    restarts: int = 0

    def next_delay(self) -> float | None:
        """None => budget exhausted (surface to the operator)."""
        if self.restarts >= self.max_restarts:
            return None
        d = min(self.backoff_base * (2 ** self.restarts), self.backoff_cap)
        self.restarts += 1
        return d
