"""Fault-tolerant training loop: data pipeline -> jitted train step ->
async checkpoints, with heartbeats, step watchdog and restart-from-latest.

Runs for real on CPU with smoke configs (examples/train_lm.py trains a
~small LM for a few hundred steps); the identical step function is what
the dry-run lowers on the production meshes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DataPipeline
from repro.launch import steps as ST
from repro.sharding.ctx import MeshCtx
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint)
from repro.train.fault_tolerance import HeartbeatMonitor, StepGuard
from repro.train.optimizer import OptConfig


@dataclass
class RunConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    step_timeout: float = 300.0
    seed: int = 0


def train(cfg: ModelConfig, ctx: MeshCtx, run: RunConfig,
          data_cfg: DataConfig | None = None,
          oc: OptConfig = OptConfig()) -> dict:  # noqa: B008
    data_cfg = data_cfg or DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=2)
    pipeline = DataPipeline(data_cfg).start()
    ckpt = AsyncCheckpointer(run.ckpt_dir)
    hb = HeartbeatMonitor(n_hosts=1)

    step_fn = jax.jit(ST.make_train_step(cfg, ctx, oc), donate_argnums=(0,))

    # --- init or restore --------------------------------------------------
    start_step = 0
    state = ST.init_train_state(cfg, ctx, jax.random.PRNGKey(run.seed), oc)
    last = latest_step(run.ckpt_dir)
    if last is not None:
        state, manifest = restore_checkpoint(run.ckpt_dir, last, state)
        pipeline.restore(manifest["extra"].get("data", {"cursor": 0}))
        start_step = last
        print(f"[train] restored step {last} from {run.ckpt_dir}")

    losses = []
    t0 = time.time()
    with ctx.mesh:
        for step in range(start_step, run.steps):
            batch_np = pipeline.next_batch()
            if batch_np is None:
                raise RuntimeError("data pipeline starved")
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()
                     if k != "chunk_id"}
            with StepGuard(run.step_timeout):
                state, metrics = step_fn(state, batch)
            hb.beat(0, step)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % run.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({time.time() - t0:.1f}s)", flush=True)
            if run.ckpt_every and (step + 1) % run.ckpt_every == 0:
                ckpt.save(step + 1, state)
    ckpt.wait()
    pipeline.stop()
    return {"state": state, "losses": losses,
            "final_loss": float(np.mean(losses[-5:]))}
