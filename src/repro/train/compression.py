"""Gradient compression: int8 error-feedback quantization.

Distributed-optimization trick (DESIGN.md §8): gradients are quantized to
int8 (per-leaf absmax scaling) before the data-parallel all-reduce, cutting
gradient collective bytes 4x vs fp32 / 2x vs bf16; the quantization error
is carried in a residual buffer and added back next step (error feedback —
unbiased in the long run, standard convergence guarantees).

Plugs into the train step around the grad sync: under GSPMD the reduction
is implicit in the partitioned graph, so the compression path is expressed
with shard_map: local grads -> quantize -> psum(int32 accumulate is exact)
-> dequantize. Works on any grads pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.sharding.compat import shard_map
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


def quantize(g, residual):
    """-> (q int8, scale f32 scalar, new_residual)."""
    gf = g.astype(F32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_res = gf - q.astype(F32) * scale
    return q, scale, new_res


def dequantize(q, scale):
    return q.astype(F32) * scale


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def compressed_allreduce(stacked_grads, stacked_residuals, ctx):
    """Error-feedback int8 all-reduce over the data axes.

    Leaves carry per-shard local grads stacked on a leading dim of size
    ``ctx.data_size`` (sharded over the data axes). Each shard quantizes
    its (grad + residual) with a *shared* absmax scale (one scalar pmax),
    the int8 payloads are summed exactly in int32, and the mean is
    dequantized — gradient collective bytes drop 4x vs fp32.

    Returns (mean_grads [leading dim 1 per shard -> same stacked shape,
    every shard holding the mean], new_residuals)."""
    ba = ctx.batch_axes
    n = ctx.data_size

    def leaf(g, r):
        def block(gb, rb):
            gf = gb.astype(F32) + rb
            # one shared scale across shards so int32 accumulation
            # dequantizes exactly
            amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), ba)
            scale = amax / 127.0 + 1e-12
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            acc = jax.lax.psum(q.astype(jnp.int32), ba)
            out = acc.astype(F32) * scale / n
            new_r = gf - q.astype(F32) * scale       # error feedback
            return out, new_r

        spec = P(ba, *([None] * (g.ndim - 1)))
        return shard_map(block, mesh=ctx.mesh,
                         in_specs=(spec, spec), out_specs=(spec, spec),
                         check_vma=False)(g, r)

    flat_g, tdef = jax.tree.flatten(stacked_grads)
    flat_r = jax.tree.leaves(stacked_residuals)
    outs = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))
