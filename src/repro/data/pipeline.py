"""Multi-threaded synthetic-data pipeline, synchronized by Reciprocating
runtime locks (the paper's algorithm doing real work in its own framework).

Producer threads generate tokenized batches (deterministic per shard+epoch,
so restarts are reproducible from a cursor); a bounded buffer hands them to
the training loop. Both the shard cursor and the buffer are guarded by
``ReciprocatingLock`` — the contended hot path under many loader threads,
exactly the lock's design point. Pull-based consumption means one slow
producer never head-of-line-blocks training (straggler isolation).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core.runtime.reciprocating import ReciprocatingLock


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 1024
    seq_len: int = 128
    global_batch: int = 8
    n_shards: int = 16
    buffer_size: int = 8
    n_workers: int = 4


class ShardCursor:
    """Deterministic, restart-able position in the virtual dataset."""

    def __init__(self, n_shards: int):
        self._lock = ReciprocatingLock()
        self._next = 0
        self.n_shards = n_shards

    def claim(self) -> int:
        with self._lock:
            idx = self._next
            self._next += 1
            return idx

    def state(self) -> int:
        with self._lock:
            return self._next

    def restore(self, value: int) -> None:
        with self._lock:
            self._next = value


class BoundedBuffer:
    """Reciprocating-locked bounded queue (condition-variable free waits
    are kept short; the lock's constant-time paths keep handoff cheap)."""

    def __init__(self, capacity: int):
        self._lock = ReciprocatingLock()
        self._items: list = []
        self.capacity = capacity
        self._closed = False

    def put(self, item, timeout: float = 120.0) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if self._closed:
                    return False
                if len(self._items) < self.capacity:
                    self._items.append(item)
                    return True
            time.sleep(0.001)
        return False

    def get(self, timeout: float = 120.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if self._items:
                    return self._items.pop(0)
                if self._closed:
                    return None
            time.sleep(0.001)
        return None

    def close(self) -> None:
        with self._lock:
            self._closed = True


def synth_batch(cfg: DataConfig, chunk_id: int) -> dict:
    """Deterministic synthetic LM batch (restart-reproducible). Tokens
    follow a noisy affine bigram process (x' = 5x+7 mod V, 10% noise), so
    a competent model drives CE well below the ln(V) uniform floor —
    the learnability signal the training tests assert on."""
    rng = np.random.default_rng(chunk_id * 9973 + 17)
    B, S, V = cfg.global_batch, cfg.seq_len + 1, cfg.vocab_size
    toks = np.zeros((B, S), np.int32)
    toks[:, 0] = rng.integers(0, V, B)
    noise = rng.random((B, S)) < 0.1
    rand = rng.integers(0, V, (B, S))
    for t in range(1, S):
        nxt = (5 * toks[:, t - 1] + 7) % V
        toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
            "mask": np.ones((cfg.global_batch, cfg.seq_len), np.float32),
            "chunk_id": chunk_id}


class DataPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.cursor = ShardCursor(cfg.n_shards)
        self.buffer = BoundedBuffer(cfg.buffer_size)
        self._threads: list = []
        self._stop = threading.Event()

    def _worker(self) -> None:
        while not self._stop.is_set():
            chunk = self.cursor.claim()
            batch = synth_batch(self.cfg, chunk)
            if not self.buffer.put(batch):
                return

    def start(self) -> "DataPipeline":
        for _ in range(self.cfg.n_workers):
            t = threading.Thread(target=self._worker, daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def next_batch(self) -> dict | None:
        return self.buffer.get()

    def checkpoint_state(self) -> dict:
        return {"cursor": self.cursor.state()}

    def restore(self, state: dict) -> None:
        self.cursor.restore(state["cursor"])

    def stop(self) -> None:
        self._stop.set()
        self.buffer.close()
        for t in self._threads:
            t.join(timeout=2.0)
