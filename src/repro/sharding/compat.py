"""Version-compatibility shims for moving jax APIs.

``shard_map`` has lived in three places across jax releases:

* ``jax.experimental.shard_map.shard_map``  (0.4.x, the pinned toolchain)
* ``jax.sharding.shard_map`` / ``jax.shard_map``  (newer releases, after
  graduation from experimental)

Import it from here (``from repro.sharding.compat import shard_map``) so
model/train code is insulated from the move.
"""
from __future__ import annotations

import contextlib
import inspect

try:                                    # newest: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:
    try:                                # experimental home (jax 0.4.x)
        from jax.experimental.shard_map import shard_map as _shard_map
    except ImportError:                 # interim home
        from jax.sharding import shard_map as _shard_map  # type: ignore

# The replication-check kwarg was renamed check_rep -> check_vma when
# shard_map graduated. Callers use the new name; translate for old jax.
if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    def shard_map(f, /, *args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, *args, **kwargs)

def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg) only exist on
    newer jax; 0.4.x meshes are implicitly Auto, so plain ``make_mesh`` is
    equivalent there.
    """
    import jax
    with contextlib.suppress(AttributeError, TypeError):
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    try:        # jax >= 0.4.35, no AxisType yet
        return jax.make_mesh(shape, axis_names)
    except AttributeError:   # older still: build the Mesh by hand
        import math
        import numpy as np
        from jax.sharding import Mesh
        n = math.prod(shape)
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axis_names)


__all__ = ["shard_map", "make_mesh"]
