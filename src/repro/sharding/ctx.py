"""Mesh context threaded through model code.

``MeshCtx`` names the mesh axes used by the model layer implementations
(shard_map MoE dispatch, sharding constraints). ``batch_axes`` is
``("data",)`` single-pod or ``("pod", "data")`` multi-pod.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    batch_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


def constrain(x, ctx: "MeshCtx | None", *dims):
    """with_sharding_constraint helper: 'B' -> batch axes, 'M' -> model
    axis, None -> replicated; dims whose size doesn't divide the assigned
    axes stay replicated."""
    import jax
    if ctx is None or ctx.mesh.size == 1:
        return x
    spec = []
    for i, d in enumerate(dims):
        if d == "B":
            spec.append(ctx.batch_axes if x.shape[i] % ctx.data_size == 0
                        else None)
        elif d == "M":
            spec.append(ctx.model_axis if x.shape[i] % ctx.model_size == 0
                        else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, ctx.sharding(*spec))


def trivial_ctx() -> MeshCtx:
    """1x1 mesh on the default device — used by CPU smoke tests."""
    from repro.sharding.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    return MeshCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
