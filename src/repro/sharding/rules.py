"""Logical-axis -> mesh-axis rules (MaxText-style).

Parameters are 2-D FSDP-sharded: the "embed" dim maps to the data axes and
the head/mlp/vocab/expert dims map to the model axis, so parameter and
optimizer-state memory scales with the full device count (ZeRO); weights are
all-gathered per layer at use (XLA overlaps the gathers under the
latency-hiding scheduler).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.ctx import MeshCtx


def rules(ctx: MeshCtx, serve: bool = False) -> dict:
    """Train: 2-D FSDP (embed dims over data) — ZeRO memory scaling, one
    weight gather per layer. Serve: dense weights are model-sharded ONLY
    ("no ZeRO at inference"): decode is weight-streaming-bound and per-step
    data-axis gathers would dominate its HBM traffic (EXPERIMENTS §Perf,
    granite decode cell). MoE expert tables stay 2-D even at serve time
    (they are far larger than HBM/16 and the decode path reads only the
    active experts)."""
    return {
        "embed": () if serve else ctx.batch_axes,
        "expert_embed": ctx.batch_axes,
        "vocab": (ctx.model_axis,),
        "heads": (ctx.model_axis,),
        "kv_heads": (ctx.model_axis,),
        "mlp": (ctx.model_axis,),
        "expert_shard": (ctx.model_axis,),
        "layers": (),                      # scanned dim, never sharded
        None: (),
    }


def spec_for(axes: tuple, ctx: MeshCtx, serve: bool = False) -> P:
    r = rules(ctx, serve)
    out = []
    for a in axes:
        m = r.get(a, ())
        if not m:
            out.append(None)
        elif len(m) == 1:
            out.append(m[0])
        else:
            out.append(tuple(m))          # e.g. ("pod", "data")
    return P(*out)


def shardings_for(axes_tree, ctx: MeshCtx, shapes_tree=None,
                  serve: bool = False):
    """Map a logical-axes pytree (tuples as leaves) to NamedShardings.

    When ``shapes_tree`` (matching tree of ShapeDtypeStructs/arrays) is
    given, any dim whose size is not divisible by its assigned mesh axes is
    left replicated (e.g. mamba2's concatenated in_proj output dim)."""
    def spec_leaf(axes):
        return spec_for(axes, ctx, serve)

    if shapes_tree is None:
        return jax.tree.map(
            lambda a: NamedSharding(ctx.mesh, spec_leaf(a)), axes_tree,
            is_leaf=lambda x: isinstance(x, tuple))

    def leaf(axes, shaped):
        spec = list(spec_leaf(axes))
        for i, m in enumerate(spec):
            if m is None:
                continue
            names = m if isinstance(m, tuple) else (m,)
            n = 1
            for nm in names:
                n *= int(ctx.mesh.shape[nm])
            if shaped.shape[i] % n != 0:
                spec[i] = None
        return NamedSharding(ctx.mesh, P(*spec))

    return jax.tree.map(leaf, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
