"""Hostile OS: throughput collapse and grace under oversubscription.

The benchmark everyone runs — pinned threads, dedicated cores, never a
preemption — is the one regime a production lock never sees. This tour
drives the scheduler model (DESIGN.md §L1 "Scheduler model",
``core/sim/sched.py``) from dedicated cores up to 4x oversubscription
and watches who survives:

* ``reciprocating`` / ``ticket`` — pure spinners: a descheduled waiter
  (or worse, a descheduled *holder*) stalls everyone; throughput
  collapses by an order of magnitude.
* ``spin_then_park`` — spins briefly, then parks: parked waiters are
  off-core (they don't burn their timeslice), so the lock degrades by
  percent, not decades — the Fissile-style story, and the reason
  spin-then-park exists.

The whole scheduler ladder per lock is ONE ``SimEngine.grid`` call:
schedulers lower to four scalars (``LoweredSched``) and ride the batch
as stacked data under a single XLA program.

Run: PYTHONPATH=src python examples/hostile_os.py [--threads 8]
"""
import argparse

from repro.core.sim.engine import SimEngine, Workload
from repro.core.sim.sched import resolve

LOCKS = ("reciprocating", "ticket", "spin_then_park")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16_000)
    args = ap.parse_args()
    T = args.threads

    # dedicated cores -> timesliced 1x -> oversubscribed 2x -> 4x,
    # plus the adversarial lock-holder-preemption profile.
    ladder = ["dedicated", "fair:2500x1", "fair:2500x2", "fair:2500x4",
              "holder-bane"]
    print("schedulers:")
    for s in ladder:
        sc = resolve(s)
        print(f"  {sc.name:14s} {sc.summary()}")

    print(f"\n{'lock':15s} {'scheduler':14s} {'thr/kcyc':>9s} "
          f"{'vs dedicated':>12s} {'preempts':>9s} {'unfair':>7s}")
    for lock in LOCKS:
        eng = SimEngine(lock, n_threads=T,
                        workload=Workload(0, True, args.steps))
        g = eng.grid(seeds=range(3), schedulers=ladder)
        base = g.cell(scheduler="dedicated").result.throughput
        for c in g:
            r = c.result
            print(f"{lock:15s} {c.scheduler:14s} {r.throughput:9.3f} "
                  f"{r.throughput / max(base, 1e-9):11.2%} "
                  f"{r.preempts:9d} {r.unfairness:7.2f}")
        print(f"{'':15s} ({len(ladder)} schedulers x 3 seeds = "
              f"{g.compiles} XLA compile)")

    print("\nReading the table: the spinners hold their dedicated-core "
          "throughput until the cores run out (oversub > 1), then "
          "collapse — every preempted spinner blocks the queue for a "
          "full scheduling gap. spin_then_park sheds its timeslice by "
          "parking, so 4x oversubscription costs it percent-level "
          "throughput and the holder-bane profile barely registers. "
          "This is Fig. 1's ranking inverted: the 'slow' parking lock "
          "wins everywhere a real OS is in the loop.")


if __name__ == "__main__":
    main()
