"""Define your own lock in ~20 lines — the LockSpec phase DSL quickstart.

Authors a test-and-set lock with backoff as a declarative phase spec
(DESIGN.md §L2), compiles it with ``compile_spec``, and benches it —
*without registering it anywhere* — against locks from the zoo on the
coherence machine.

Run:  PYTHONPATH=src python examples/define_a_lock.py [--threads 12]
"""
import argparse
from functools import partial

from repro.core.locks.compile import compile_spec, describe_spec
from repro.core.locks.dsl import DELAY, NCS, SPIN_EQ, STORE, XCHG
from repro.core.sim.api import bench_lock
from repro.core.sim.machine import CostModel


def tas_backoff(s):
    """Test-and-set with a fixed backoff after a failed grab — the whole
    lock: one declared word, four steps, no raw PCs or magic addresses."""
    flag = s.word("flag")

    @s.step("entry")
    def grab(c):
        return c.op(XCHG(flag, 1), arrive=True)

    @s.step("entry")
    def check(c):                       # c.res = old flag value
        got = c.res == 0
        return c.when(got, c.enter_cs(admit=True), c.op(DELAY(24)))

    @s.step("waiting")
    def repoll(c):
        return c.op(SPIN_EQ(flag, 0), to="grab")

    @s.step("release")
    def unlock(c):
        return c.op(STORE(flag, 0), to=NCS)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threads", type=int, default=12)
    ap.add_argument("--steps", type=int, default=16_000)
    args = ap.parse_args()

    d = describe_spec(tas_backoff)
    print(f"spec `{d['name']}`: "
          + " ".join(f"{p}:{steps}" for p, steps in d["phases"].items()
                     if steps))
    print()
    print(f"{'algorithm':<15s} {'thr/kcyc':>9s} {'miss/ep':>8s} "
          f"{'unfair':>7s} {'bypass':>7s}")
    rows = [("tas_backoff", partial(compile_spec, tas_backoff)),
            ("ttas", None), ("mcs", None), ("reciprocating", None)]
    for name, builder in rows:
        r = bench_lock(name, args.threads, n_steps=args.steps,
                       n_replicas=2, cost=CostModel(n_nodes=2),
                       builder=builder)
        print(f"{name:<15s} {r.throughput:>9.3f} {r.miss_per_episode:>8.2f} "
              f"{r.unfairness:>7.2f} {r.bypass_bound:>7d}")
    print("\nExpect: the custom TAS lock behaves like ttas (global spinning"
          "\ncollapse, unfair barging admission); the queue locks keep"
          "\nconstant misses/episode and bounded bypass. Add your spec to"
          "\ncore/locks/specs.py::SPECS to register it with the harness.")


if __name__ == "__main__":
    main()
