"""Serving example: continuous batching through the unified scheduler
core (docs/SERVING.md) on a reduced starcoder2-3b.

Demonstrates the pieces the serving guide walks through:

* per-step admission — requests arrive staggered (``arrival`` is in
  scheduler steps) and are admitted into slots as they free up;
* per-request early exit — ``max_new`` varies, so finished requests
  leave their slot instead of riding the batch to the longest request;
* paged KV with prefix sharing — two prompt families share a 16-token
  prefix (``prefix_id``/``prefix_len``), so later family members pin the
  cached prefix blocks copy-free.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import model as M_
from repro.serve.engine import GenRequest, InferenceEngine


def main() -> None:
    cfg = smoke_config(get_config("starcoder2-3b"))
    params = M_.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, policy="reciprocating", max_batch=4,
                          max_seq=128, block_size=16)
    print(f"[serve_lm] paged={eng.paged} "
          f"pool={eng.pool.cap if eng.pool else 0} blocks")

    rng = np.random.default_rng(7)
    families = {f: rng.integers(1, 97, 16, dtype=np.int32)
                for f in range(2)}
    t0 = time.time()
    for i in range(10):
        fam = i % 2
        prompt = np.concatenate(
            [families[fam],
             rng.integers(1, 97, int(rng.integers(2, 8)), dtype=np.int32)])
        eng.submit(GenRequest(
            rid=i, tokens=prompt, prefix_id=fam, prefix_len=16,
            max_new=int(rng.integers(3, 13)),
            arrival=float(i)))                  # staggered arrivals
    done = eng.run()
    dt = time.time() - t0

    toks = sum(len(r.out) for r in done)
    for r in done[:3]:
        print(f"req {r.rid}: {len(r.tokens)} prompt toks, "
              f"admitted@{r.admitted:.0f} finished@{r.finished:.0f} "
              f"hit={r.prefill_hit:.2f} -> {r.out}")
    c = eng.counters
    from repro.bench.suites import static_batch_slot_steps
    naive = static_batch_slot_steps(done, max_batch=4)
    print(f"[serve_lm] {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"(CPU smoke config)")
    print(f"[serve_lm] {int(eng.core.time)} scheduler steps, "
          f"{c.slot_steps} slot-steps (detached-segment batching would "
          f"burn {naive}); pool "
          f"{eng.pool.stats.to_dict() if eng.pool else {}}")


if __name__ == "__main__":
    main()
