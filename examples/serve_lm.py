"""Serving example: batched generation through the inference engine with
reciprocating admission (segments = detached batches), on a reduced
starcoder2-3b.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import model as M_
from repro.serve.engine import GenRequest, InferenceEngine


def main() -> None:
    cfg = smoke_config(get_config("starcoder2-3b"))
    params = M_.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, policy="reciprocating", max_batch=4)

    rng = np.random.default_rng(7)
    t0 = time.time()
    for i in range(10):
        prompt = rng.integers(1, 97, int(rng.integers(4, 24)),
                              dtype=np.int32)
        eng.submit(GenRequest(rid=i, tokens=prompt, max_new=8))
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    for r in done[:3]:
        print(f"req {r.rid}: {len(r.tokens)} prompt toks -> {r.out}")
    print(f"[serve_lm] {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"(CPU smoke config)")


if __name__ == "__main__":
    main()
