"""Quickstart: the paper's lock in 60 seconds.

1. run the Reciprocating Lock on the JAX coherence machine and reproduce
   the paper's headline numbers (4 misses/episode, Table-2 palindrome),
2. use the host runtime port to guard a real multi-threaded counter,
3. peek at one dry-run cell (if artifacts exist).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import threading

from repro.core.locks.reference import ALGORITHMS
from repro.core.runtime.reciprocating import ReciprocatingLock
from repro.core.sim.engine import SimEngine, Workload
from repro.core.sim.interleave import run as ref_run
from repro.core.sim.topology import smp


def main() -> None:
    # --- 1a. coherence machine: Table 1 -----------------------------------
    # SimEngine is the session API: pick a lock, a machine topology and a
    # workload, then run/ensemble/grid (DESIGN.md §L1).
    wl = Workload(ncs_max=0, cs="local", n_steps=15_000)
    r = SimEngine("reciprocating", topology=smp(10), n_threads=10,
                  workload=wl).run(seed=0)
    print(f"[sim] reciprocating: {r.miss_per_episode:.2f} coherence misses "
          f"per contended episode (paper Table 1: 4)")
    r2 = SimEngine("clh", topology=smp(10), n_threads=10,
                   workload=wl).run(seed=0)
    print(f"[sim] clh:           {r2.miss_per_episode:.2f} (paper: 5)")

    # --- 1b. Table 2 palindrome -------------------------------------------
    res = ref_run(ALGORITHMS["reciprocating"](5), 5, n_ops=6000, policy="rr")
    cyc = res.cycle()
    print(f"[ref] sustained-contention admission cycle: "
          f"{''.join('ABCDE'[t] for t in cyc)} (paper Table 2; "
          f"unfairness {res.unfairness():.2f}x, bound 2x)")

    # --- 2. host runtime lock, real threads ---------------------------------
    lock = ReciprocatingLock()
    counter = {"v": 0}

    def work():
        for _ in range(10_000):
            with lock:
                counter["v"] += 1

    ts = [threading.Thread(target=work) for _ in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    print(f"[runtime] 4 threads x 10k increments -> {counter['v']} "
          f"(no lost updates)")

    # --- 3. a dry-run cell ----------------------------------------------------
    import glob
    import json
    cells = sorted(glob.glob("benchmarks/artifacts/dryrun_*single.json"))
    if cells:
        d = json.load(open(cells[0]))
        if d.get("status") == "ok":
            t = d["roofline_seconds"]
            print(f"[dryrun] {d['arch']} x {d['shape']}: dominant="
                  f"{d['dominant']}, terms(ms)="
                  f"{ {k: round(v*1e3, 1) for k, v in t.items()} }")


if __name__ == "__main__":
    main()
