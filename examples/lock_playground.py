"""Lock playground: compare every algorithm on the coherence machine and
watch the paper's phenomena appear. Pick the machine with ``--topology``
(`flat:2` = the historical 2-node flat model; try `epyc-2s`, `smp:16`,
`numa:4x4`, `ccx` — catalogue: `python -m repro.bench list --topologies`).

Run:  PYTHONPATH=src python examples/lock_playground.py [--threads 16]
"""
import argparse

from repro.core.sim.engine import SimEngine, Workload
from repro.core.sim.machine import CostModel
from repro.core.sim.topology import resolve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threads", type=int, default=16)
    ap.add_argument("--steps", type=int, default=20_000)
    ap.add_argument("--topology", default="flat:2",
                    help="machine model: flat:N or a topology preset/"
                         "shorthand (see `repro.bench list --topologies`)")
    args = ap.parse_args()
    if args.topology.startswith("flat"):
        _, _, n = args.topology.partition(":")
        machine = CostModel(n_nodes=int(n or 2))
    else:
        machine = resolve(args.topology)

    print(f"{'algorithm':<15s} {'thr/kcyc':>9s} {'miss/ep':>8s} "
          f"{'remote/ep':>9s} {'latency':>8s} {'unfair':>7s} {'bypass':>7s}")
    for alg in ("reciprocating", "retrograde", "mcs", "clh", "hemlock",
                "ticket", "anderson", "ttas",
                "hapax", "fissile", "spin_then_park"):
        eng = SimEngine(alg, topology=machine, n_threads=args.threads,
                        workload=Workload(n_steps=args.steps))
        r = eng.ensemble(range(2))
        print(f"{alg:<15s} {r.throughput:>9.3f} {r.miss_per_episode:>8.2f} "
              f"{r.remote_per_episode:>9.2f} {r.latency:>8.0f} "
              f"{r.unfairness:>7.2f} {r.bypass_bound:>7d}")
    print("\nExpect: reciprocating leads throughput with ~4 misses/episode;"
          "\nticket/ttas collapse (global spinning); unfairness ~2x for the"
          "\nreciprocating family (paper §9.2), ~1x for FIFO locks. Of the"
          "\nDSL-authored variants (locks-ext): hapax stays FIFO-fair at"
          "\nconstant cost, fissile barges (throughput up, fairness gone),"
          "\nspin_then_park pays the park/unpark handoff tax.")


if __name__ == "__main__":
    main()
