"""Measured vs modeled: the same lock, both backends, side by side.

One ``LockSpec`` lowers to one ``LockIR`` (DESIGN.md §L2 "one IR, two
backends") and runs twice here:

* **sim** — the discrete-time coherence machine prices every micro-op
  with a ``CostModel`` and reports episodes per kilocycle (model time);
* **measured** — the same IR as a Pallas kernel over the device atomics
  layer reports episodes per wall-second and per kilo-slice (real time;
  interpret mode on CPU, compiled kernels on an accelerator).

Two things to watch in the output:

1. With a *uniform* cost model (every op = 1 cycle) the sim dispatches
   exactly the kernel's round-robin schedule — for deterministic-order
   locks (queue and ticket families) the admission-order prefixes
   printed at the bottom are identical, episode for episode.  That is
   the backend-agreement property CI gates on.  Racy locks (ttas) may
   legitimately differ: who wins a race is a tie-break the model does
   not pin down.
2. With the *default* (miss-priced) model, relative throughput between
   locks reshuffles: coherence misses dominate, which is the paper's
   point — and the gap between that column and the measured one is what
   ``bench/calibrate.py`` fits.

Run: PYTHONPATH=src python examples/measured_vs_sim.py [--threads 4]
"""
import argparse

import numpy as np

from repro.core.locks.pallas_backend import backends, run_measured
from repro.core.locks.programs import PROGRAMS
from repro.core.sim.machine import CostModel, run_machine

LOCKS = ("reciprocating", "ticket", "mcs", "ttas")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=800)
    args = ap.parse_args()
    T, rounds = args.threads, args.rounds
    sim_steps = rounds * T                    # same op budget per tier

    print("# backends")
    for row in backends():
        mark = "ok " if row["available"] else "-- "
        print(f"  {mark}{row['name']:17s} {row['detail']}")

    uni = CostModel(hit=1, local_miss=1, remote_miss=1)
    print(f"\n# {T} threads, {rounds} rounds, maximal contention")
    print(f"{'lock':15s} {'sim eps/kcyc':>13s} {'uniform':>9s} "
          f"{'meas eps/ks':>12s} {'meas eps/s':>11s} {'coll':>5s}")
    orders = {}
    for name in LOCKS:
        prog = PROGRAMS[name](T, ncs_max=0, cs_shared=True)
        s_def = run_machine(prog, T, sim_steps, cm=CostModel(), seed=0)
        s_uni = run_machine(prog, T, sim_steps, cm=uni, seed=0)
        r = run_measured(name, T, rounds)
        orders[name] = (
            np.asarray(s_uni.adm_log)[:int(s_uni.adm_cnt)][:16].tolist(),
            r.admissions[:min(r.admission_counts, 16)].tolist())

        def eps_kcyc(st):
            cyc = float(np.max(np.asarray(st.time)))
            return float(np.sum(np.asarray(st.episodes))) / max(cyc, 1) * 1e3

        print(f"{name:15s} {eps_kcyc(s_def):13.2f} {eps_kcyc(s_uni):9.1f} "
              f"{r.episodes_per_kslice:12.2f} {r.throughput_eps:11.0f} "
              f"{r.collisions:5d}")

    print("\n# admission order, uniform-cost sim vs Pallas (first 16)")
    for name, (sim_o, pal_o) in orders.items():
        tag = "==" if sim_o == pal_o[:len(sim_o)] or pal_o == \
            sim_o[:len(pal_o)] else "!="
        print(f"  {name:15s} sim {sim_o}\n  {'':15s} pal {pal_o}  [{tag}]")


if __name__ == "__main__":
    main()
