"""Topology tour: one lock, many machines, one compile.

Walks the machine-model layer (DESIGN.md §L1):

1. pick machines — presets, factories, shorthand strings;
2. run one lock across all of them with ``SimEngine.grid`` (the seed and
   topology axes are stacked cost-matrix data, so the whole grid is a
   single XLA program);
3. see the paper's remote-miss story fall out: queue locks keep O(1)
   remote transfers per episode while global spinning scales with the
   machine's NUMA spread — and thread *placement* alone moves the
   numbers.

Run: PYTHONPATH=src python examples/topology_tour.py [--threads 8]
"""
import argparse

from repro.core.sim.engine import SimEngine, Workload
from repro.core.sim.topology import PRESETS, ccx, numa, smp


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--steps", type=int, default=10_000)
    ap.add_argument("--locks", default="reciprocating,mcs,ticket")
    args = ap.parse_args()
    T = args.threads

    # 1. machines: a degenerate SMP box, two NUMA shapes, a chiplet part
    #    with scatter pinning, and a named real-machine profile.
    machines = [
        smp(T),
        numa(2, (T + 1) // 2),
        numa(4, (T + 3) // 4),
        ccx(sockets=2, ccx_per_socket=2, per_ccx=(T + 3) // 4),
        numa(2, (T + 1) // 2).interleave(),
        "epyc-2s",                       # preset name (list --topologies)
    ]
    print("machines:")
    for m in machines:
        t = PRESETS[m] if isinstance(m, str) else m
        print(f"  {t.name:22s} {t.summary()}")

    # 2. one grid per lock: seeds x machines in a single jit.
    print(f"\n{'lock':15s} {'machine':22s} {'thr/kcyc':>9s} "
          f"{'miss/ep':>8s} {'remote/ep':>9s}")
    for lock in args.locks.split(","):
        eng = SimEngine(lock, n_threads=T,
                        workload=Workload(0, "local", args.steps))
        g = eng.grid(seeds=range(3), topologies=machines)
        for c in g:
            r = c.result
            print(f"{lock:15s} {c.topology:22s} {r.throughput:9.3f} "
                  f"{r.miss_per_episode:8.2f} {r.remote_per_episode:9.2f}")
        print(f"{'':15s} ({len(machines)} machines x 3 seeds = "
              f"{g.compiles} XLA compile)")

    print("\nReading the table: miss/ep is machine-invariant (the lock's "
          "algorithmic coherence cost); remote/ep and throughput are "
          "topology effects. Queue locks hold remote/ep ~O(1) as the "
          "machine fragments; interleaved placement splits neighbours "
          "across sockets and global spinning pays for it.")


if __name__ == "__main__":
    main()
