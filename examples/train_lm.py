"""End-to-end training driver: train a reduced granite-3-2b for a few
hundred steps on CPU with the full substrate — multi-threaded data
pipeline (Reciprocating-locked), AdamW, remat scan, async checkpoints,
restart-from-checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil

from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig
from repro.sharding.ctx import trivial_ctx
from repro.train.optimizer import OptConfig
from repro.train.train_loop import RunConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    cfg = smoke_config(get_config("granite-3-2b")).replace(
        n_layers=4, d_model=256, d_ff=512, vocab_size=512)
    ctx = trivial_ctx()
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)

    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=2000,
                   master_fp32=True)
    out = train(cfg, ctx, RunConfig(steps=args.steps, ckpt_dir=args.ckpt,
                                    ckpt_every=100, log_every=20),
                data_cfg=data, oc=oc)
    first = out["losses"][0]
    print(f"[train_lm] loss {first:.3f} -> {out['final_loss']:.3f} over "
          f"{args.steps} steps "
          f"({'LEARNING' if out['final_loss'] < first - 0.1 else 'check!'})")

    # restart demo: resume from the checkpoint for a few more steps
    out2 = train(cfg, ctx, RunConfig(steps=args.steps + 20,
                                     ckpt_dir=args.ckpt, ckpt_every=1000,
                                     log_every=20), data_cfg=data, oc=oc)
    print(f"[train_lm] resumed to step {args.steps + 20}; final loss "
          f"{out2['final_loss']:.3f}")


if __name__ == "__main__":
    main()
