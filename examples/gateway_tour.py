"""Fleet-gateway tour: four replicas, five routers, one tenant trace.

Walks the fleet tier of SERVING.md §8 end to end:

1. generate a seeded multi-tenant trace (Zipf tenants with shared
   system prompts, Poisson bursts, heavy-tailed decode lengths);
2. drive it through a 4-replica fleet under every routing policy;
3. compare global cache-hit rate, TTFT, load imbalance and goodput —
   and watch the radix prefix tree / eviction-coherence machinery work.

Run:  PYTHONPATH=src python examples/gateway_tour.py
"""
import time

from repro.serve.gateway import ROUTERS, FleetGateway
from repro.serve.prefix_tree import RadixPrefixTree
from repro.serve.traces import TraceSpec, generate

N_REPLICAS = 4
SPEC = TraceSpec(n_requests=6_000, n_tenants=96, burst_rate=0.1, seed=0)


def tree_demo() -> None:
    """The router's index in isolation: advertise, match, evict."""
    print("=== the global radix prefix tree (serve/prefix_tree.py) ===")
    tree = RadixPrefixTree(block_tokens=4)
    prompt = list(range(12))            # 3 full blocks
    ids = tree.insert(prompt, replica=0)
    tree.insert(prompt[:8], replica=1)  # replica 1 holds 2 of the 3
    print(f"advertised chain node ids {ids} -> "
          f"match = {tree.match(prompt)}  (replica: depth in blocks)")
    # replica 0's pool evicts block 1 -> it drops out of depths >= 2
    tree.evict(ids[1], replica=0)
    print(f"after replica 0 evicts block 1 -> match = {tree.match(prompt)}"
          "  (runs must be contiguous from the root)")
    print()


def main() -> None:
    tree_demo()
    print(f"=== {SPEC.n_requests} requests, {SPEC.n_tenants} tenants, "
          f"{N_REPLICAS} replicas x 8 slots ===")
    print(f"{'router':14s} {'hit':>6s} {'mean_ttft':>9s} {'p99_ttft':>8s} "
          f"{'imbal':>6s} {'goodput':>8s} {'tree':>5s} {'wall':>6s}")
    rows = {}
    for name in ROUTERS:
        t0 = time.time()
        gw = FleetGateway(n_replicas=N_REPLICAS, router=name,
                          max_slots=8, pool_blocks=160, seed=1)
        s = gw.run(generate(SPEC))
        rows[name] = s
        print(f"{name:14s} {s['hit_rate']:6.3f} {s['mean_ttft']:9.1f} "
              f"{s['p99_ttft']:8.0f} {s['load_imbalance']:6.2f} "
              f"{s['goodput_tok_per_step']:8.1f} {s['tree_nodes']:5d} "
              f"{time.time() - t0:5.1f}s")
    print()
    p, r = rows["prefix"], rows["random"]
    print(f"prefix vs random: hit {p['hit_rate']:.3f} vs {r['hit_rate']:.3f}, "
          f"mean TTFT {p['mean_ttft']:.1f} vs {r['mean_ttft']:.1f} steps")
    print("(`reciprocating` adds the paper's entry-segment dispatch on "
          "top of the\n prefix-aware targets — bursts drain newest-first "
          "with bounded bypass,\n while their tenant prefix is hottest; "
          "see SERVING.md §8.)")


if __name__ == "__main__":
    main()
