"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus a prefill->decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.launch.steps import (
    batch_struct, init_train_state, make_train_step,
)
from repro.models import decode as D_
from repro.models import model as M_
from repro.sharding.ctx import trivial_ctx
from repro.configs.base import ShapeConfig

SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")


def smoke_batch(cfg, key, kind="train"):
    shape = ShapeConfig("smoke", 64, 2, kind)
    struct = batch_struct(cfg, shape, kind=kind)

    def mk(s):
        if s.dtype == jnp.int32:
            return jax.random.randint(key, s.shape, 0, min(cfg.vocab_size, 97),
                                      jnp.int32)
        if s.dtype == jnp.float32 and len(s.shape) == 2:   # mask
            return jnp.ones(s.shape, jnp.float32)
        return jax.random.normal(key, s.shape, jnp.float32).astype(s.dtype) * 0.05

    return jax.tree.map(mk, struct,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.fixture(scope="module")
def ctx():
    return trivial_ctx()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, ctx):
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, ctx, key)
    batch = smoke_batch(cfg, key)
    step = jax.jit(make_train_step(cfg, ctx))
    with ctx.mesh:
        new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert loss > 0
    # the optimizer actually took a step (bf16 params may round back)
    assert int(new_state["opt"]["step"]) == 1
    mu_norm = sum(float(jnp.sum(jnp.abs(m)))
                  for m in jax.tree.leaves(new_state["opt"]["mu"]))
    assert mu_norm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch, ctx):
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = M_.init_params(cfg, key)
    batch = smoke_batch(cfg, key, kind="prefill")
    with ctx.mesh:
        logits, cache = jax.jit(
            lambda p, b: D_.prefill_step(p, b, cfg, ctx))(params, batch)
        assert logits.shape == (2, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache = jax.jit(
            lambda p, c, t: D_.decode_step(p, c, t, cfg, ctx))(
                params, cache, tok)
        assert logits2.shape == (2, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits2, np.float32)).all()
        assert int(cache["pos"][0]) == 65
