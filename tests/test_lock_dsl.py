"""Differential and property tests for the LockSpec phase DSL
(core/locks/dsl.py, compile.py, specs.py).

* **Differential**: every re-expressed paper lock compiles to a program
  whose machine states are *bit-identical* to the frozen pre-redesign
  hand-rolled handler tables (``tests/_legacy_programs.py``) on pinned
  seeds, across CS profiles and thread counts — the redesign is a pure
  re-authoring, and ``summarize_ensemble`` therefore yields identical
  ``BenchResult`` metrics.
* **Invariants** (every compiled spec, new variants included): mutual
  exclusion on the shared CS word, progress / no lost wakeups, and the
  observed single-thread admission-interleave bound (<= 2 for the
  reciprocating family — the paper's §2 bypass <= 1 plus one legitimate
  turn — and <= 1 for the strict-FIFO locks).
* **New-variant behaviour**: hapax is FIFO-fair with T-independent
  coherence cost; fissile's barging TS fast path buys throughput at a
  fairness cost; spin_then_park's park/unpark CostModel hooks are
  measurable.
* **DSL quality**: authoring mistakes (unknown label/register, missing
  release phase, bad phase name, dangling fallthrough) are compile-time
  ``SpecError``s, and specs are introspectable for the CLI catalogue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _legacy_programs import LEGACY_PROGRAMS
from repro.core.locks.compile import compile_spec, describe_spec
from repro.core.locks.dsl import NCS, NOP, STORE, SpecError
from repro.core.locks.programs import NEW_VARIANTS, PROGRAMS
from repro.core.sim.api import (
    admission_bypass_bound, bench_lock, summarize_ensemble,
)
from repro.core.sim.machine import CostModel, run_machine

PAPER_ALGS = sorted(LEGACY_PROGRAMS)
ALL_ALGS = sorted(PROGRAMS)

# Machine-state fields that constitute "the metrics": everything
# summarize_ensemble aggregates, plus memory and the admission log.
STATE_FIELDS = ("mem", "episodes", "misses", "remote", "inval_recv",
                "lat_sum", "adm_log", "adm_cnt", "time")


def _run(prog, T, steps, seed, n_nodes=1):
    cm = CostModel(n_nodes=n_nodes)
    return jax.jit(lambda: run_machine(prog, T, steps, cm, seed))()


# --- differential: compiled specs vs the frozen seed tables -----------------

@pytest.mark.parametrize("name", PAPER_ALGS)
def test_spec_identical_to_seed_tables(name):
    """Pinned-seed 2-thread sweep over the CS profiles, plus a contended
    6-thread NUMA cell: state-for-state equality with the pre-DSL zoo."""
    cases = [(2, {"cs_shared": True}), (2, {"cs_shared": False}),
             (2, {"cs_shared": "ro", "ncs_max": 60}),
             (2, {"ncs_max": 120}), (6, {"cs_shared": False})]
    for T, kw in cases:
        legacy = LEGACY_PROGRAMS[name](T, **kw)
        spec = PROGRAMS[name](T, **kw)
        for seed in (0, 3):
            sl = _run(legacy, T, 2500, seed, n_nodes=2)
            sn = _run(spec, T, 2500, seed, n_nodes=2)
            for f in STATE_FIELDS:
                assert np.array_equal(np.asarray(getattr(sl, f)),
                                      np.asarray(getattr(sn, f))), \
                    (name, T, kw, seed, f)


@pytest.mark.parametrize("name", ["reciprocating", "mcs"])
def test_benchresult_identical_to_seed_tables(name):
    """The aggregated BenchResult (the numbers RESULTS.md prints) is
    identical too, on a pinned 2-seed ensemble."""
    T = 4

    def ensemble(builder):
        runs = [_run(builder(T, ncs_max=0, cs_shared=True), T, 3000, s)
                for s in (0, 1)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *runs)

    rl = summarize_ensemble(name, T, ensemble(LEGACY_PROGRAMS[name]))
    rn = summarize_ensemble(name, T, ensemble(PROGRAMS[name]))
    for f in ("throughput", "episodes", "miss_per_episode",
              "inval_per_episode", "remote_per_episode", "latency",
              "unfairness", "bypass_bound"):
        assert getattr(rl, f) == getattr(rn, f), (name, f)
    assert np.array_equal(rl.admissions, rn.admissions)


# --- invariants for every compiled spec -------------------------------------

@pytest.mark.parametrize("name", ALL_ALGS)
def test_mutual_exclusion_on_cs_word(name):
    """mem[CS] counts successful read-modify-write episodes; any mutual
    exclusion violation loses updates and breaks the equality (modulo the
    <= T threads still inside the CS at the horizon)."""
    T = 5
    s = _run(PROGRAMS[name](T, ncs_max=0, cs_shared=True), T, 8000, 1)
    eps, cs = int(s.episodes.sum()), int(s.mem[4])
    assert eps > 50, f"{name}: no progress"
    assert eps - T <= cs <= eps + T, (name, cs, eps)


@pytest.mark.parametrize("name", ALL_ALGS)
def test_progress_no_lost_wakeups(name):
    """A lost wakeup wedges the system; doubling the horizon must keep
    completing episodes at a comparable rate."""
    T = 4
    prog = PROGRAMS[name](T, ncs_max=0, cs_shared=False)
    e1 = int(_run(prog, T, 5000, 2).episodes.sum())
    e2 = int(_run(prog, T, 10000, 2).episodes.sum())
    assert e1 > 20, f"{name}: wedged early"
    assert e2 > e1 * 1.5, (name, e1, e2)


def test_admission_interleave_bounds():
    """Observed single-thread admission-interleave bound from the machine
    admission log (``admission_bypass_bound``): <= 2 for the reciprocating
    family (paper §2: one bypass + one legitimate turn), <= 1 for the
    strict-FIFO locks — including the new hapax and spin_then_park."""
    segment = {"reciprocating": 2, "retrograde": 2}
    fifo = {"ticket": 1, "mcs": 1, "clh": 1, "hemlock": 1, "anderson": 1,
            "hapax": 1, "spin_then_park": 1}
    for name, bound in {**segment, **fifo}.items():
        s = _run(PROGRAMS[name](6, ncs_max=0, cs_shared=False), 6, 30000, 0)
        got = admission_bypass_bound(s.adm_log, s.adm_cnt)
        assert got <= bound, (name, got, bound)
        assert int(s.adm_cnt) >= 512      # the log window actually filled
    # fissile's barging fast path is visibly *not* FIFO
    s = _run(PROGRAMS["fissile"](6, ncs_max=0, cs_shared=False), 6, 30000, 0)
    assert admission_bypass_bound(s.adm_log, s.adm_cnt) > 2


def test_admission_bypass_bound_ring_wrap():
    """The ``cnt >= K`` branch: once the admission ring has wrapped, the
    chronological order is ``np.roll(log, -(cnt % K))`` — decoding the
    raw buffer order would split interleave runs across the seam.

    Ring of K=5 holding 7 admissions: chronological tail is
    [0, 1, 1, 1, 0] (thread 1 admitted 3x between thread 0's turns), laid
    out in the buffer as [1, 0 | 0, 1, 1] with the write cursor at 2."""
    log = np.array([1, 0, 0, 1, 1])
    assert admission_bypass_bound(log, np.array(7)) == 3
    # naive (unwrapped) reading of the same buffer would say 2
    assert admission_bypass_bound(log, np.array(4)) == 2
    # exact-fill boundary: cnt == K wraps with zero rotation
    full = np.array([0, 1, 1, 0, 1])
    assert admission_bypass_bound(full, np.array(5)) == 2
    # unfilled ring (cnt < K): only the first cnt entries are decoded,
    # and the -1 padding is ignored
    part = np.array([0, 1, 0, -1, -1])
    assert admission_bypass_bound(part, np.array(3)) == 1
    # replica-stacked logs take the worst bound across replicas
    stacked = np.stack([log, np.array([0, 1, 0, 1, 0])])
    assert admission_bypass_bound(stacked, np.array([7, 5])) == 3


# --- new-variant behaviour ---------------------------------------------------

def test_hapax_fifo_fair_constant_paths():
    r8 = bench_lock("hapax", 8, n_steps=20_000, n_replicas=2,
                    cost=CostModel(n_nodes=1))
    r16 = bench_lock("hapax", 16, n_steps=30_000, n_replicas=2,
                     cost=CostModel(n_nodes=1))
    assert r8.unfairness < 1.1                     # FIFO-fair
    assert r8.bypass_bound <= 1
    # value-based admission keeps coherence cost T-independent
    assert abs(r16.miss_per_episode - r8.miss_per_episode) < 1.0


def test_fissile_fast_path_and_barging():
    r1 = bench_lock("fissile", 1, n_steps=4000, n_replicas=1,
                    cost=CostModel(n_nodes=1))
    assert r1.miss_per_episode < 0.5               # uncontended TS path
    rf = bench_lock("fissile", 12, n_steps=20_000, n_replicas=2)
    rt = bench_lock("ticket", 12, n_steps=20_000, n_replicas=2)
    assert rf.throughput > rt.throughput * 2       # barging buys throughput
    assert rf.unfairness > rt.unfairness + 0.5     # ...at a fairness cost


def test_spin_then_park_cost_hooks_measurable():
    """The CostModel park/unpark hooks change what the machine measures,
    in the directions the PARK_EQ contract (machine.py table) pins down:
    park and unpark are *private* time — the park charge accrues to the
    sleeper when it blocks and the unpark syscall to the waker's own
    timeline after the waking store — so dearer hooks never slow the
    bus-time handoff itself. What they do is delay the waker's
    *re-arrival*, thinning the queue: mean arrive->admit latency drops
    and bus-time throughput does not degrade. At T=2 the handoff beats
    the spin budget, the park path never engages, and the hooks are
    exactly inert (bit-identical metrics)."""
    kw = {"n_steps": 12_000, "n_replicas": 2}
    free = bench_lock("spin_then_park", 8,
                      cost=CostModel(n_nodes=1, park_cost=0, unpark_cost=0),
                      **kw)
    dear = bench_lock("spin_then_park", 8,
                      cost=CostModel(n_nodes=1, park_cost=25,
                                     unpark_cost=300), **kw)
    # hooks are live: the parked equilibrium shifts measurably...
    assert dear.latency < free.latency * 0.95
    # ...but private time never shows up on the bus-time denominator
    assert dear.throughput > free.throughput * 0.95
    assert dear.episodes >= free.episodes
    # T=2: waits shorter than the probe budget -> no thread ever parks,
    # so the very same hooks are inert
    f2 = bench_lock("spin_then_park", 2,
                    cost=CostModel(n_nodes=1, park_cost=0, unpark_cost=0),
                    **kw)
    d2 = bench_lock("spin_then_park", 2,
                    cost=CostModel(n_nodes=1, park_cost=25,
                                   unpark_cost=300), **kw)
    assert (d2.episodes, d2.latency, d2.throughput) == \
        (f2.episodes, f2.latency, f2.throughput)


# --- DSL quality: compile-time errors and introspection ----------------------

def test_compile_time_spec_errors():
    def no_release(s):
        @s.step("doorway")
        def a(c):
            return c.op(NOP(), to=NCS)

    def bad_phase(s):
        @s.step("loitering")
        def a(c):
            return c.op(NOP(), to=NCS)

    def bad_label(s):
        @s.step("doorway")
        def a(c):
            return c.op(NOP(), to="nowhere")

        @s.step("release")
        def b(c):
            return c.op(NOP(), to=NCS)

    def bad_register(s):
        @s.step("release")
        def a(c):
            c.r.ghost = 1
            return c.op(NOP(), to=NCS)

    def dangling_fallthrough(s):
        @s.step("release")
        def a(c):
            return c.op(NOP())          # last step cannot fall through

    def too_many_words(s):
        for i in range(5):
            s.word(f"w{i}")

        @s.step("release")
        def a(c):
            return c.op(NOP(), to=NCS)

    for author in (no_release, bad_phase, bad_label, bad_register,
                   dangling_fallthrough, too_many_words):
        with pytest.raises(SpecError):
            compile_spec(author, 2)


def test_custom_spec_end_to_end():
    """The README quickstart path: author a minimal lock, compile it, run
    it un-registered through bench_lock — in ~15 lines."""
    def tas(s):
        flag = s.word("flag")

        @s.step("entry")
        def grab(c):
            from repro.core.locks.dsl import XCHG
            return c.op(XCHG(flag, 1), arrive=True)

        @s.step("entry")
        def check(c):
            got = c.res == 0
            return c.when(got, c.enter_cs(admit=True),
                          c.op(NOP(), to="grab"))

        @s.step("release")
        def unlock(c):
            return c.op(STORE(flag, 0), to=NCS)

    from functools import partial
    r = bench_lock("tas", 4, n_steps=6000, n_replicas=1,
                   cost=CostModel(n_nodes=1),
                   builder=partial(compile_spec, tas))
    assert r.episodes > 100
    assert r.name == "tas"


def test_describe_spec_summary():
    from repro.core.locks.specs import SPECS
    d = describe_spec(SPECS["reciprocating"], n_threads=4)
    assert d["name"] == "reciprocating"
    assert d["phases"]["doorway"] == ["prepare", "push", "consume_tail"]
    assert d["regs"] == ["succ", "eos"]
    assert ("element", 4, "per-thread") in d["regions"]
    for name in ALL_ALGS:
        dd = describe_spec(SPECS[name], n_threads=2)
        assert dd["phases"]["release"], name     # release phase everywhere
    assert set(NEW_VARIANTS) <= set(ALL_ALGS)
