"""End-to-end behaviour tests for the whole system."""
import os
import subprocess
import sys

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig
from repro.sharding.ctx import trivial_ctx
from repro.train.optimizer import OptConfig
from repro.train.train_loop import RunConfig, train

OC = OptConfig(lr=3e-3, warmup_steps=5, total_steps=1000, master_fp32=True)


def test_training_learns(tmp_path):
    """A tiny LM trained for 80 steps on bigram-structured synthetic data
    must drive CE well below the ln(V) uniform floor."""
    cfg = smoke_config(get_config("granite-3-2b")).replace(
        n_layers=2, d_model=128, d_ff=256, vocab_size=256)
    data = DataConfig(vocab_size=256, seq_len=64, global_batch=4)
    out = train(cfg, trivial_ctx(),
                RunConfig(steps=80, ckpt_dir=str(tmp_path), ckpt_every=0,
                          log_every=1000),
                data_cfg=data, oc=OC)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 1.0, (first, last)


def test_training_restart_resumes(tmp_path):
    """Kill-and-restart: a checkpointed run resumes from the saved step and
    continues to the target."""
    cfg = smoke_config(get_config("starcoder2-3b")).replace(
        n_layers=2, d_model=128, d_ff=256, vocab_size=256)
    data = DataConfig(vocab_size=256, seq_len=32, global_batch=2)
    ckpt = str(tmp_path / "ck")
    train(cfg, trivial_ctx(),
          RunConfig(steps=20, ckpt_dir=ckpt, ckpt_every=10,
                    log_every=1000), data_cfg=data, oc=OC)
    # "crash" after step 20 (ckpt at 20); resume to 30
    out2 = train(cfg, trivial_ctx(),
                 RunConfig(steps=30, ckpt_dir=ckpt, ckpt_every=10,
                           log_every=1000), data_cfg=data, oc=OC)
    assert len(out2["losses"]) == 10          # only steps 20..30 re-run
    assert np.isfinite(out2["final_loss"])


def test_multi_device_dryrun_cell():
    """Integration: one real dry-run cell (lower+compile on the 256-chip
    mesh) in a subprocess — the XLA device-count flag must never leak into
    this test process."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-3-2b", "--shape", "decode_32k", "--mesh", "single",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=420, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "ok" in r.stdout
    # and this process still sees exactly one device
    assert len(jax.devices()) == 1


def test_serving_deterministic_across_policies():
    """The admission policy must change ORDER only, never token values."""
    from repro.models import model as M_
    from repro.serve.engine import GenRequest, InferenceEngine
    cfg = smoke_config(get_config("granite-3-2b")).replace(
        n_layers=2, d_model=128, d_ff=256, vocab_size=256)
    params = M_.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 97, 8, dtype=np.int32) for _ in range(4)]

    def run(policy):
        eng = InferenceEngine(cfg, params, policy=policy, max_batch=4)
        for i, p in enumerate(prompts):
            eng.submit(GenRequest(rid=i, tokens=p, max_new=4))
        return {r.rid: r.out for r in eng.run()}

    a, b = run("fifo"), run("reciprocating")
    assert a == b
