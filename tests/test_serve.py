"""Serving-stack tests (docs/SERVING.md): paged-KV pool invariants,
engine-vs-reference decode equivalence, the shared scheduler core,
per-policy starvation bounds, and the ``serve`` bench suite round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.core import DrainStalled, ServeCore
from repro.serve.kv_cache import KVPoolExhausted, PagedKVPool


# ---------------------------------------------------------------------------
# paged KV pool
# ---------------------------------------------------------------------------
def test_pool_alloc_release_accounting():
    pool = PagedKVPool(8, reserve_null=True)
    assert pool.null_block == 0
    a = pool.alloc("r1", 3)
    b = pool.alloc("r2", 2)
    assert 0 not in a + b and len(set(a + b)) == 5
    assert pool.n_pinned == 5 and pool.n_free == 2
    pool.release("r1")                       # no prefix: blocks freed
    assert pool.n_pinned == 2 and pool.n_free == 5 and pool.n_cached == 0
    pool.release("r2", prefix_id=9, keep_blocks=1)
    assert pool.n_pinned == 0 and pool.n_cached == 1
    assert pool.lookup(9, 4) == [b[0]]       # first table block retained
    pool.check()


def test_pool_lru_eviction_order():
    pool = PagedKVPool(4)
    pool.insert("a", 2)
    pool.insert("b", 2)                      # pool now full
    assert pool.hit_fraction("a", 2) == 1.0  # touch: a becomes MRU
    pool.insert("c", 2)                      # evicts LRU = b's blocks
    assert pool.hit_fraction("b", 2) == 0.0
    assert pool.hit_fraction("a", 2) == 1.0
    assert pool.stats.evictions == 2
    pool.check()


def test_pool_pinned_never_evicted_and_exhaustion():
    pool = PagedKVPool(4)
    ids = pool.alloc("r1", 3)
    pool.insert("p", 3)                      # needs 3, only 1 free: evicts
    assert pool.hit_fraction("p", 3) < 1.0   # its own earlier entries
    for bid in ids:                          # pinned ids never recycled
        assert bid in pool.table_of("r1")
    with pytest.raises(KVPoolExhausted):
        pool.alloc("r2", 3)                  # 3 pinned + <=1 evictable
    assert pool.table_of("r2") == []         # failed alloc left no state
    pool.check()


def test_pool_prefix_sharing_refcounts():
    pool = PagedKVPool(8)
    a = pool.alloc("r1", 2)
    pool.release("r1", prefix_id=7, keep_blocks=2)
    got = pool.share("r2", 7, 2)
    assert got == a                          # copy-free: same physical ids
    pool.insert("x", 6)                      # churn: shared ids survive
    assert pool.lookup(7, 2) == a
    pool.release("r2", prefix_id=7, keep_blocks=2)
    assert pool.n_pinned == 0
    assert pool.stats.shared_hits == 2
    pool.check()


# ---------------------------------------------------------------------------
# model engine (smoke config shared across tests)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def smoke_lm():
    from repro.configs import get_config, smoke_config
    from repro.models import model as M_
    cfg = smoke_config(get_config("starcoder2-3b")).replace(
        n_layers=2, d_model=128, d_ff=256, vocab_size=256)
    params = M_.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reference_greedy(cfg, params, prompt, n, max_seq=64):
    """Dense-cache greedy decode with full headroom (prefill right-padded
    to ``max_seq`` so generated positions never ring-wrap): the oracle
    the paged and dense-slot engines must reproduce token-for-token."""
    from repro.models import decode as D_
    from repro.sharding.ctx import trivial_ctx
    ctx = trivial_ctx()
    L = len(prompt)
    toks = np.zeros((1, max_seq), np.int32)
    toks[0, :L] = prompt
    logits, cache = jax.jit(
        lambda p, b, li: D_.prefill_step(p, b, cfg, ctx, last_index=li))(
        params, {"tokens": jnp.asarray(toks)},
        jnp.asarray([L - 1], jnp.int32))
    cache["pos"] = jnp.asarray([L], jnp.int32)   # pads are future slots
    out, tok = [], jnp.argmax(logits, -1).astype(jnp.int32)
    step = jax.jit(lambda p, c, t: D_.decode_step(p, c, t, cfg, ctx))
    for _ in range(n):
        out.append(int(tok[0]))
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return out


@pytest.mark.parametrize("mode", ["paged", "paged_chunked", "dense"])
def test_engine_matches_reference(smoke_lm, mode):
    from repro.serve.engine import GenRequest, InferenceEngine
    cfg, params = smoke_lm
    prompt = np.random.default_rng(7).integers(1, 97, 11, dtype=np.int32)
    ref = _reference_greedy(cfg, params, prompt, 6)
    kw = {"max_batch": 2, "max_seq": 64, "block_size": 8}
    if mode == "paged_chunked":
        kw["prefill_chunk"] = 4              # prefill rides the decode loop
    if mode == "dense":
        kw["paged"] = False                  # force the fallback executor
    eng = InferenceEngine(cfg, params, **kw)
    assert eng.paged == (mode != "dense")
    eng.submit(GenRequest(rid=0, tokens=prompt, max_new=6))
    done = eng.run()
    assert done[0].out == ref


def test_engine_early_exit_and_per_step_admission(smoke_lm):
    """A short request frees its slot mid-run; the queued request is
    admitted into it while the long request is still decoding."""
    from repro.serve.engine import GenRequest, InferenceEngine
    cfg, params = smoke_lm
    rng = np.random.default_rng(5)
    eng = InferenceEngine(cfg, params, policy="fifo", max_batch=2,
                          max_seq=64, block_size=8)
    long = GenRequest(rid=0, tokens=rng.integers(1, 97, 8, np.int32),
                      max_new=20)
    short = GenRequest(rid=1, tokens=rng.integers(1, 97, 8, np.int32),
                       max_new=2)
    queued = GenRequest(rid=2, tokens=rng.integers(1, 97, 8, np.int32),
                        max_new=2)
    for r in (long, short, queued):
        eng.submit(r)
    done = eng.run()
    assert [r.rid for r in done] == [1, 2, 0]
    assert queued.admitted < long.finished   # continuous, not segmented
    assert len(long.out) == 20 and len(short.out) == 2
    # early exit: finished slots stop burning decode compute
    assert eng.counters.slot_steps < 3 * 20


def test_engine_prefix_sharing_end_to_end(smoke_lm):
    from repro.serve.engine import GenRequest, InferenceEngine
    cfg, params = smoke_lm
    rng = np.random.default_rng(9)
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64,
                          block_size=8)
    shared = rng.integers(1, 97, 16, dtype=np.int32)
    r1 = GenRequest(rid=0, tokens=shared, max_new=4, prefix_id=3)
    eng.submit(r1)
    first = eng.run()[0].out
    r2 = GenRequest(rid=1, tokens=shared, max_new=4, prefix_id=3)
    eng.submit(r2)
    second = eng.run()[0].out
    assert r1.prefill_hit == 0.0 and r2.prefill_hit == 1.0
    assert first == second                   # sharing never changes tokens
    eng.pool.check()


def test_misaligned_chunk_never_corrupts_shared_blocks(smoke_lm):
    """A sharer admitted with a chunk ending mid-block must not scatter
    its right-padding into the prefix blocks a concurrent request is
    still attending over."""
    from repro.serve.engine import GenRequest, InferenceEngine
    cfg, params = smoke_lm
    rng = np.random.default_rng(11)
    shared = rng.integers(1, 97, 16, dtype=np.int32)
    ref = _reference_greedy(cfg, params, shared, 12)
    eng = InferenceEngine(cfg, params, policy="fifo", max_batch=2,
                          max_seq=64, block_size=8, prefill_chunk=12)
    c = GenRequest(rid=0, tokens=shared, max_new=1, prefix_id=5,
                   arrival=0.0)           # seeds the prefix cache
    a = GenRequest(rid=1, tokens=shared, max_new=12, prefix_id=5,
                   arrival=8.0)           # pins the cached blocks
    b = GenRequest(rid=2, tokens=shared, max_new=2, prefix_id=5,
                   arrival=12.0)          # admitted while A is decoding
    for r in (c, a, b):
        eng.submit(r)
    eng.run()
    assert a.prefill_hit == 1.0 and b.prefill_hit == 1.0
    assert b.admitted < a.finished        # B's chunk landed mid-A
    assert a.out == ref                   # ...without perturbing A


def test_idle_slot_never_writes_released_blocks(smoke_lm):
    """A freed slot keeps decoding as a dummy row; its stale block table
    must not let it scatter garbage into the retiree's now-cached prefix
    blocks while the slot sits empty."""
    from repro.serve.engine import GenRequest, InferenceEngine
    cfg, params = smoke_lm
    rng = np.random.default_rng(13)
    shared = rng.integers(1, 97, 8, dtype=np.int32)
    eng = InferenceEngine(cfg, params, policy="fifo", max_batch=2,
                          max_seq=64, block_size=8)
    a = GenRequest(rid=0, tokens=shared, max_new=2, prefix_id=6,
                   arrival=0.0)
    filler = GenRequest(rid=1, tokens=rng.integers(1, 97, 8, np.int32),
                        max_new=16, arrival=0.0)   # keeps the run alive
    late = GenRequest(rid=2, tokens=shared, max_new=2, prefix_id=6,
                      arrival=10.0)                # slot idles 0..10
    for r in (a, filler, late):
        eng.submit(r)
    while a.finished < 0:               # drive until A retires...
        eng.core.step()
    bid = eng.pool.lookup(6, 1)[0]      # ...caching its prefix block
    snap = np.asarray(eng.executor.k_pool[bid])
    eng.core.step()                     # A's old slot decodes as a dummy
    eng.core.step()                     # row while it sits empty
    np.testing.assert_array_equal(       # cached block must be pristine
        snap, np.asarray(eng.executor.k_pool[bid]))
    eng.run()
    assert late.prefill_hit == 1.0      # served from A's cached block
    assert late.out == a.out


def test_duplicate_valued_requests_do_not_collide(smoke_lm):
    """Requests compare by identity, not field equality: two submissions
    with identical rid/prompt must both complete."""
    from repro.serve.engine import GenRequest, InferenceEngine
    cfg, params = smoke_lm
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64,
                          block_size=8)
    eng.submit(GenRequest(rid=0, tokens=prompt, max_new=3))
    eng.submit(GenRequest(rid=0, tokens=prompt.copy(), max_new=3))
    done = eng.run()
    assert len(done) == 2 and done[0].out == done[1].out


def test_sim_and_engine_share_scheduler_core(smoke_lm):
    """The acceptance property: both frontends drive serve.core."""
    from repro.serve.engine import InferenceEngine
    from repro.serve.scheduler import ContinuousBatcher
    cfg, params = smoke_lm
    sim = ContinuousBatcher(max_batch=2)
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64)
    assert type(sim.core) is ServeCore and type(eng.core) is ServeCore
    assert type(sim.core.queue) is type(eng.core.queue)
    assert type(sim.pool) is type(eng.pool) is PagedKVPool


# ---------------------------------------------------------------------------
# starvation bounds + drain behaviour (sim frontend)
# ---------------------------------------------------------------------------
def test_starvation_bound_by_policy():
    """Reciprocating's bounded bypass keeps the worst wait near FIFO's;
    raw LIFO starves its tail (unbounded bypass)."""
    from repro.bench.suites import scheduler_drive
    waits = {p: scheduler_drive(p, n_req=200, mean_gap=8.0,
                                seed=0)["max_wait"]
             for p in ("fifo", "reciprocating", "lifo")}
    assert waits["fifo"] <= waits["reciprocating"] <= waits["lifo"]
    assert waits["lifo"] > 2.0 * waits["reciprocating"]


def test_drain_raises_instead_of_silent_return():
    from repro.serve.scheduler import ContinuousBatcher, Request
    sched = ContinuousBatcher(max_batch=1)
    sched.submit(Request(rid=0, arrival=0.0, prefix_id=0, prefix_blocks=2,
                         prompt_blocks=2, decode_tokens=500))
    with pytest.raises(DrainStalled):
        sched.drain(max_steps=10)


def test_request_work_fields_are_declared():
    """_prefill_left/_decode_left are dataclass fields, not step()-time
    attribute injection."""
    import dataclasses

    from repro.serve.scheduler import Request
    names = {f.name for f in dataclasses.fields(Request)}
    assert {"_prefill_left", "_decode_left"} <= names


# ---------------------------------------------------------------------------
# serve bench suite
# ---------------------------------------------------------------------------
def test_serve_suite_schema_roundtrip(tmp_path):
    from repro.bench import BenchConfig, load_result, run_suite, save_result
    from repro.bench.report import render_markdown
    doc = run_suite("serve", BenchConfig(quick=True, verbose=False))
    p = str(tmp_path / "serve.json")
    save_result(doc, p)                      # refuses invalid documents
    back = load_result(p)
    by_name = {e["name"]: e for e in back["experiments"]}
    sweep = by_name["serve_policy_load"]
    assert [s["label"] for s in sweep["series"]] == [
        "fifo", "lifo", "reciprocating", "reciprocating_mitigated"]
    for s in sweep["series"]:
        for pt in s["points"]:
            assert pt["throughput_rps"] > 0
            assert 0.0 <= pt["prefix_hit_rate"] <= 1.0
    assert {r["policy"] for r in by_name["serve_pool"]["rows"]} \
        == {s["label"] for s in sweep["series"]}
    md = render_markdown(back)
    assert "Serving" in md and "offered_load" in md
