"""Mutant-spec regression corpus for the static verifier.

Each mutant hand-breaks exactly one paper property of a known-good lock
spec; the tests assert the *specific* analyzer pass catches it — the CFG
gate (``core/locks/cfg.py``) for shape violations, the exhaustive
small-scope model checker (``core/locks/verify.py``) for interleaving
violations — and that the error carries useful provenance (phase/label
for structural findings, a minimal counterexample trace for model-check
findings). Positive controls pin the structural facts of the real zoo
to the paper's comparison table.
"""
from __future__ import annotations

import pytest

from repro.bench import report
from repro.bench.cli import main as cli_main
from repro.core.locks import cfg, specs, verify
from repro.core.locks.compile import compile_spec
from repro.core.locks.dsl import (
    CAS, FAA, NCS, NOP, SPIN_EQ, STORE, XCHG, SpecError,
)


# ---------------------------------------------------------------------------
# Positive controls: the zoo's structural facts match the paper table
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,doorway,release,spin,footprint", [
    ("reciprocating", "constant", "wait_free", "own", 1),
    ("ticket", "constant", "wait_free", "shared", 0),
    ("mcs", "constant", "waits", "own", 2),
    ("clh", "constant", "wait_free", "cell", 1),
    ("ttas", "none", "wait_free", "shared", 0),
    ("reciprocating_abortable", "constant", "unbounded", "cell", 0),
])
def test_structural_facts_match_paper_table(name, doorway, release, spin,
                                            footprint):
    facts = cfg.analyze(specs.SPECS[name], 4, name)
    assert facts.doorway_grade == doorway
    assert facts.release_grade == release
    assert facts.spin_level == spin
    assert facts.footprint == footprint
    assert cfg.check_spec(facts) == []


def test_reciprocating_doorway_is_two_ops():
    facts = cfg.analyze(specs.SPECS["reciprocating"], 4, "reciprocating")
    assert facts.doorway.loop_free
    assert facts.doorway.bound == 2


# ---------------------------------------------------------------------------
# Mutant: remote spin cell (declared own, actually a dynamic/shared cell)
# ---------------------------------------------------------------------------
def _anderson_claims_own(s):
    specs.anderson(s)
    s.expect(spin="own")        # BUG: anderson spins on a *rotating* slot


def _ticket_claims_own(s):
    specs.ticket(s)
    s.expect(spin="own")        # BUG: ticket spins on the shared grant word


def test_mutant_remote_spin_cell_caught():
    with pytest.raises(SpecError) as ei:
        compile_spec(_anderson_claims_own, 4, name="anderson_claims_own")
    msg = str(ei.value)
    assert "anderson_claims_own" in msg          # lock-name provenance
    assert "declared spin='own' but analysis proves 'cell'" in msg


def test_mutant_shared_spin_declared_local_caught():
    with pytest.raises(SpecError) as ei:
        compile_spec(_ticket_claims_own, 4, name="ticket_claims_own")
    msg = str(ei.value)
    assert "declared spin='own' but analysis proves 'shared'" in msg
    assert "SPIN_EQ" in msg                      # the culprit op is named


# ---------------------------------------------------------------------------
# Mutant: loop in the doorway (undeclared -> safety-floor SpecError)
# ---------------------------------------------------------------------------
def _doorway_loop(s):
    tk, gr = s.word("ticket"), s.word("grant")
    s.regs("my")

    @s.step("doorway")
    def take(c):
        return c.op(FAA(tk, 1))

    @s.step("doorway")
    def got(c):
        c.r.my = c.res
        unlucky = (c.res % 7) == 3
        return c.when(unlucky, c.op(NOP(), to="take"),   # BUG: doorway loop
                      c.op(SPIN_EQ(gr, c.res), arrive=True))

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def bump(c):
        return c.op(FAA(gr, 1), to=NCS)


def test_mutant_doorway_loop_caught():
    with pytest.raises(SpecError) as ei:
        compile_spec(_doorway_loop, 4, name="doorway_loop")
    msg = str(ei.value)
    assert "doorway phase has a loop" in msg
    assert "take" in msg and "got" in msg        # the cycle is spelled out
    assert 'doorway="unbounded"' in msg          # ... and the opt-out hint


# ---------------------------------------------------------------------------
# Mutant: second waiting element (footprint understated)
# ---------------------------------------------------------------------------
def _mcs_understated(s):
    specs.mcs(s)
    s.expect(footprint=1)       # BUG: mcs nodes are two per-thread words


def test_mutant_second_waiting_element_caught():
    with pytest.raises(SpecError) as ei:
        compile_spec(_mcs_understated, 4, name="mcs_understated")
    msg = str(ei.value)
    assert ("declared footprint=1 but the spec touches 2 "
            "sequestered per-thread word(s)") in msg


# ---------------------------------------------------------------------------
# Stale declaration (two-sided check): claiming *weaker* than proven
# ---------------------------------------------------------------------------
def _mcs_stale_release(s):
    specs.mcs(s)
    s.expect(release="wait_free")   # stale: the handoff CAS path waits


def test_stale_declaration_is_an_error_too():
    with pytest.raises(SpecError) as ei:
        compile_spec(_mcs_stale_release, 4, name="mcs_stale_release")
    msg = str(ei.value)
    assert "declared release='wait_free' but analysis proves 'waits'" in msg
    assert "cas_done" in msg                     # step-label provenance


# ---------------------------------------------------------------------------
# Mutant: dropped wakeup (release never clears the flag)
# ---------------------------------------------------------------------------
def _ttas_dropped_wakeup(s):
    flag = s.word("flag")

    @s.step("waiting")
    def wait_free(c):
        return c.op(SPIN_EQ(flag, 0), arrive=True)

    @s.step("entry")
    def grab(c):
        return c.op(XCHG(flag, 1))

    @s.step("entry")
    def check(c):
        got = c.res == 0
        return c.when(got, c.enter_cs(admit=True),
                      c.op(SPIN_EQ(flag, 0), to="grab"))

    @s.step("release")
    def unlock(c):
        return c.op(STORE(flag, 1), to=NCS)      # BUG: leaves the lock held


def test_mutant_dropped_wakeup_caught():
    r = verify.model_check(_ttas_dropped_wakeup, 2, episodes=1,
                           name="ttas_dropped_wakeup")
    assert not r.ok
    assert r.violation in ("deadlock", "lost_wakeup")
    assert "SPIN_EQ(flag" in r.detail            # who is stuck, and where
    assert r.trace                               # minimal counterexample
    assert any("STORE(flag, 1)" in step for step in r.trace)


# ---------------------------------------------------------------------------
# Mutant: mutual-exclusion hole (admits on a *failed* CAS)
# ---------------------------------------------------------------------------
def _cas_admits_loser(s):
    flag = s.word("flag")

    @s.step("entry")
    def grab(c):
        return c.op(CAS(flag, 0, 1))

    @s.step("entry")
    def admitted(c):
        return c.enter_cs(admit=True)            # BUG: ignores the CAS result

    @s.step("release")
    def unlock(c):
        return c.op(STORE(flag, 0), to=NCS)


def test_mutant_mutual_exclusion_hole_caught():
    r = verify.model_check(_cas_admits_loser, 2, episodes=1,
                           name="cas_admits_loser")
    assert not r.ok
    assert r.violation == "mutual_exclusion"
    assert "pending CS access together" in r.detail
    assert any("CAS(flag" in step for step in r.trace)


# ---------------------------------------------------------------------------
# Mutant: FIFO violation (a barging lock declaring a bypass bound)
# ---------------------------------------------------------------------------
def _ttas_claims_fifo(s):
    specs.ttas(s)
    s.expect(bypass=1)          # BUG: ttas barges without bound


def test_mutant_fifo_violation_caught():
    v = verify.verify_lock(_ttas_claims_fifo, "ttas_claims_fifo")
    assert not v.ok
    assert v.structural_violations == []         # shape is fine ...
    assert v.check is not None and v.check.violation == "bypass"
    assert "declared bound 1" in v.check.detail  # ... the interleaving isn't
    assert v.check.trace


# ---------------------------------------------------------------------------
# Positive controls: the model checker certifies the real zoo
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["ticket", "ttas", "reciprocating"])
def test_model_check_certifies_real_locks(name):
    r = verify.model_check(specs.SPECS[name], 2, episodes=2, name=name)
    assert r.ok and r.closed
    assert "exhaustive" in r.certificate


def test_reciprocating_respects_paper_bypass_bound():
    r = verify.model_check(specs.SPECS["reciprocating"], 2, episodes=2,
                           name="reciprocating", bypass_bound=2)
    assert r.ok
    assert r.max_bypass <= 2


# ---------------------------------------------------------------------------
# Expectation-schema validation
# ---------------------------------------------------------------------------
def test_expect_rejects_unknown_key():
    with pytest.raises(SpecError, match="unknown expectation"):
        cfg.validate_expectations({"fairness": 1}, "x")


def test_expect_rejects_bad_value():
    with pytest.raises(SpecError, match="spin= must be one of"):
        cfg.validate_expectations({"spin": "local"}, "x")


def test_verify_all_rejects_unknown_lock():
    with pytest.raises(KeyError, match="unknown lock"):
        verify.verify_all(names=("nope",))


# ---------------------------------------------------------------------------
# Matrix rendering + RESULTS.md splicing
# ---------------------------------------------------------------------------
def test_matrix_structural_render():
    vs = verify.verify_all(names=("reciprocating", "ttas"), model=False)
    txt = verify.render_matrix(vs)
    assert "reciprocating" in txt and "own cell" in txt
    rows = verify.matrix_rows(vs)
    by = {r["lock"]: r for r in rows}
    # structural-only runs show the declaration, flagged as unproven
    assert by["reciprocating"]["bypass"].startswith("declared ≤2")
    assert by["ttas"]["bypass"] == "✗ declared unbounded"


def test_splice_section_roundtrip(tmp_path):
    p = str(tmp_path / "R.md")
    report.splice_section(p, report.VERIFY_HEADER, ["row-one"])
    report.splice_section(p, "## Other", ["keep-me"])
    report.splice_section(p, report.VERIFY_HEADER, ["row-new"])
    text = (tmp_path / "R.md").read_text()
    assert text.count(report.VERIFY_HEADER) == 1
    assert "row-new" in text and "row-one" not in text
    assert "## Other" in text and "keep-me" in text


# ---------------------------------------------------------------------------
# CLI surfaces
# ---------------------------------------------------------------------------
def test_cli_verify_subset(capsys):
    assert cli_main(["verify", "--lock", "ticket", "--no-results",
                     "--no-progress"]) == 0
    out = capsys.readouterr().out
    assert "ticket" in out
    assert "exhaustive" in out
