"""Differential harness for the sharded, content-cached sweep engine.

What PR-level claims these tests pin (extending the frozen-oracle
pattern of ``tests/_legacy_programs.py`` — two independent execution
paths must agree bit-for-bit, not approximately):

* **Sharded == unsharded.** ``SimEngine.grid(shard=True)`` routes the
  stacked point batch through ``shard_map`` over a device mesh;
  ``shard=False`` is the historical plain vmap. Every grid point is an
  independent element-wise simulation, so the two paths must produce
  bit-identical ``GridResult`` cells — on one device (forced mesh of 1,
  in-process) and on a real 4-device mesh including the batch-padding
  branch (subprocess, since ``XLA_FLAGS`` must be set before jax
  imports).
* **Cached == fresh.** ``bench/cache.py`` round-trips a ``BenchResult``
  through its content-addressed JSON store; a warm ``cached_grid`` must
  return cells equal field-for-field (ndarray dtypes included) to the
  cold run that stored them, with zero compiles.
* **The key is semantic.** Any change to the spec program, topology,
  scheduler, workload or seeds changes the cell key; renaming step
  labels, memory words, workload labels or scheduler presets — or
  editing docstrings — does not. Keys are pure content hashes, stable
  across processes. (Hypothesis drives the label/step invariance when
  installed; pinned parametrization otherwise, as in
  ``tests/test_hostile.py``.)
* **Compile accounting is exact, process-wide.** A session reused
  across two suites with different scheduler stacks pays exactly one
  trace per batch shape (regression: the counts below are pinned), and
  the module-level ``trace_count()`` also sees traces paid by throwaway
  engines that no session counter records — the under-count that made
  suite-level compile accounting unreliable.
"""
import json
import os
import subprocess
import sys
from dataclasses import replace

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property tests degrade to fixed parametrization
    HAVE_HYPOTHESIS = False

from repro.bench import cache as cachemod
from repro.bench import sweep
from repro.bench.registry import BenchConfig
from repro.bench import schema
from repro.core.locks.compile import compile_spec
from repro.core.locks.dsl import FAA, LOAD, NCS, SPIN_EQ, STORE
from repro.core.sim.engine import (
    SimEngine, Workload, trace_count, _lower_host, _lower_sched_host,
)
from repro.core.sim.machine import CostModel
from repro.core.sim.sched import resolve as sched_resolve

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

#: >= 5 locks x 2 topologies x 2 schedulers for the differential grid.
DIFF_LOCKS = ("reciprocating", "mcs", "ticket", "clh", "spin_then_park")
DIFF_TOPOLOGIES = ("smp:4", "numa:2x2")
DIFF_SCHEDULERS = ("dedicated", "fair-2x")
SEEDS = (0, 1)
WL = Workload(0, True, 600)

RESULT_SCALARS = ("name", "n_threads", "throughput", "episodes",
                  "miss_per_episode", "inval_per_episode",
                  "remote_per_episode", "latency", "unfairness",
                  "aborts", "preempts")
RESULT_ARRAYS = ("admissions", "admission_counts")


def assert_results_identical(a, b, ctx=""):
    for f in RESULT_SCALARS:
        assert getattr(a, f) == getattr(b, f), f"{ctx}: {f} diverged"
    for f in RESULT_ARRAYS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert x.dtype == y.dtype, f"{ctx}: {f} dtype diverged"
        assert np.array_equal(x, y), f"{ctx}: {f} diverged"


@pytest.fixture
def own_cache(tmp_path):
    """A private cache store, restoring the process-wide one after."""
    prev = cachemod._CACHE
    store = cachemod.configure(root=str(tmp_path / "cache"))
    yield store
    cachemod._CACHE = prev


# --- sharded vs unsharded ----------------------------------------------------

@pytest.mark.parametrize("lock", DIFF_LOCKS)
def test_sharded_grid_bit_identical(lock):
    """shard=True (forced shard_map, mesh of >= 1 device) against
    shard=False (plain vmap) over the full 2-topology x 2-scheduler
    grid: every cell bit-identical on pinned seeds."""
    eng = SimEngine(lock, n_threads=4, workload=WL)
    kw = {"seeds": SEEDS, "topologies": list(DIFF_TOPOLOGIES),
          "schedulers": list(DIFF_SCHEDULERS)}
    g0 = eng.grid(**kw, shard=False)
    g1 = eng.grid(**kw, shard=True)
    assert len(g0.cells) == len(g1.cells) == 4
    for c0, c1 in zip(g0.cells, g1.cells):
        assert (c0.topology, c0.scheduler) == (c1.topology, c1.scheduler)
        assert_results_identical(
            c0.result, c1.result,
            ctx=f"{lock}/{c0.topology}/{c0.scheduler}")


_MULTI_DEV_SCRIPT = r"""
import json
import numpy as np
import jax
from repro.core.sim.engine import SimEngine, Workload
checks = []
for lock in ("reciprocating", "mcs"):
    eng = SimEngine(lock, n_threads=4, workload=Workload(0, True, 600))
    # 3 seeds x 2 topologies = 6 points on 4 devices: pads to 8, trims
    kw = dict(seeds=[0, 1, 2], topologies=["smp:4", "numa:2x2"])
    g0 = eng.grid(**kw, shard=False)
    g1 = eng.grid(**kw, shard="auto")
    for c0, c1 in zip(g0.cells, g1.cells):
        a, b = c0.result, c1.result
        same = all(getattr(a, f) == getattr(b, f) for f in (
            "throughput", "episodes", "miss_per_episode",
            "inval_per_episode", "remote_per_episode", "latency",
            "unfairness", "aborts", "preempts"))
        same = same and np.array_equal(a.admissions, b.admissions)
        same = same and np.array_equal(a.admission_counts,
                                       b.admission_counts)
        checks.append(bool(same))
print(json.dumps({"devices": jax.device_count(),
                  "n_cells": len(checks), "all_equal": all(checks)}))
"""


def test_sharded_multi_device_bit_identical():
    """Real 4-device host mesh (forced via XLA_FLAGS, so it needs a
    fresh process) — ``shard="auto"`` splits the batch across devices,
    pads 6 points to 8, and must still match vmap bit-for-bit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", _MULTI_DEV_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=env, cwd=REPO)
    assert p.returncode == 0, p.stderr[-2000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["devices"] == 4
    assert out["n_cells"] == 4
    assert out["all_equal"]


# --- cached vs fresh ---------------------------------------------------------

def test_cached_grid_warm_equals_fresh(own_cache):
    kw = {"seeds": SEEDS,
          "topologies": [CostModel(n_nodes=1), CostModel(n_nodes=2)],
          "workloads": [WL], "threads": [4]}
    cold = sweep.cached_grid("reciprocating", **kw)
    assert own_cache.stats.misses == len(cold.cells)
    assert own_cache.stats.stores == len(cold.cells)
    warm = sweep.cached_grid("reciprocating", **kw)
    assert warm.compiles == 0                    # no simulation at all
    assert own_cache.stats.hits == len(cold.cells)
    for c0, c1 in zip(cold.cells, warm.cells):
        assert (c0.lock, c0.n_threads, c0.topology, c0.workload,
                c0.scheduler) == (c1.lock, c1.n_threads, c1.topology,
                                  c1.workload, c1.scheduler)
        assert_results_identical(c0.result, c1.result,
                                 ctx=f"cached {c0.topology}")


def test_bench_cell_cached_equality(own_cache):
    """The bench-harness entry point: a warm ``bench_cell`` must return
    a BenchResult equal field-for-field to the cold one."""
    cfg = BenchConfig(threads=(2,), n_steps=300, n_replicas=2,
                      verbose=False)
    cold = sweep.bench_cell("mcs", 2, cfg)
    warm = sweep.bench_cell("mcs", 2, cfg)
    assert own_cache.stats.hits >= 1
    assert_results_identical(cold, warm, ctx="bench_cell mcs")


def test_partial_hit_reruns_whole_grid(own_cache):
    """Losing one cell's entry degrades to a full (one-jit) grid rerun
    that re-stores every cell — never a partial mixed-source grid."""
    kw = {"seeds": SEEDS,
          "topologies": [CostModel(n_nodes=1), CostModel(n_nodes=2)],
          "workloads": [WL], "threads": [4]}
    sweep.cached_grid("ticket", **kw)
    # evict one of the two entries
    victims = [os.path.join(dp, f) for dp, _, fs in
               os.walk(own_cache.root) for f in fs if f.endswith(".json")]
    os.unlink(sorted(victims)[0])
    h0, s0 = own_cache.stats.hits, own_cache.stats.stores
    g = sweep.cached_grid("ticket", **kw)
    assert own_cache.stats.hits == h0           # no partial credit
    assert own_cache.stats.stores == s0 + len(g.cells)
    # and now it's fully warm again
    warm = sweep.cached_grid("ticket", **kw)
    assert warm.compiles == 0
    for c0, c1 in zip(g.cells, warm.cells):
        assert_results_identical(c0.result, c1.result, ctx="re-stored")


def test_disabled_cache_bypasses_store(own_cache):
    own_cache.enabled = False
    kw = {"seeds": (0,), "workloads": [WL], "threads": [2]}
    sweep.cached_grid("mcs", **kw)
    assert own_cache.stats.snapshot() == {"hits": 0, "misses": 0,
                                          "stores": 0}
    assert own_cache.entries() == 0


def test_no_read_still_stores(own_cache):
    """--no-cache semantics: lookups off, the store stays fresh."""
    kw = {"seeds": (0,), "workloads": [WL], "threads": [2]}
    sweep.cached_grid("clh", **kw)
    own_cache.read = False
    h0 = own_cache.stats.hits
    sweep.cached_grid("clh", **kw)
    assert own_cache.stats.hits == h0            # regenerated
    assert own_cache.entries() >= 1              # but re-stored
    own_cache.read = True
    warm = sweep.cached_grid("clh", **kw)
    assert warm.compiles == 0


# --- the cache key is semantic -----------------------------------------------

def _cell_key(lock="mcs", T=4, ncs=0, cs=True, n_steps=500,
              topology=CostModel(), sched="dedicated",  # noqa: B008
              seeds=(0, 1),
              wl_label=""):
    eng = SimEngine(lock, n_threads=T)
    wl = Workload(ncs, cs, n_steps, label=wl_label)
    fp = cachemod.program_fingerprint(eng.program(T, wl))
    return cachemod.cell_key(fp, T, wl, _lower_host(topology, T),
                             _lower_sched_host(sched, T), seeds)


SEMANTIC_MUTATIONS = [
    ("lock", "clh"),                             # different program
    ("T", 5),                                    # thread count
    ("ncs", 64),                                 # workload NCS bound
    ("cs", "local"),                             # workload CS profile
    ("n_steps", 501),                            # horizon
    ("topology", CostModel(n_nodes=2)),          # NUMA split
    ("topology", replace(CostModel(), local_miss=41)),   # one cost cycle
    ("sched", "fair-2x"),                        # scheduler family
    ("sched", "fair:2501x2"),                    # one quantum cycle
    ("seeds", (0, 2)),                           # seed value
    ("seeds", (0, 1, 2)),                        # ensemble size
]


@pytest.mark.parametrize("fld,value", SEMANTIC_MUTATIONS,
                         ids=[f"{f}={v}" for f, v in SEMANTIC_MUTATIONS])
def test_semantic_change_changes_key(fld, value):
    assert _cell_key() != _cell_key(**{fld: value})


def _check_label_invariance(wl_label, sched_rename):
    base = _cell_key()
    assert _cell_key(wl_label=wl_label) == base
    ded = sched_resolve("dedicated")
    assert _cell_key(sched=replace(ded, name=sched_rename or "x")) == base


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.text(max_size=12), st.text(min_size=1, max_size=12))
    def test_key_ignores_labels(wl_label, sched_rename):
        _check_label_invariance(wl_label, sched_rename)
else:
    @pytest.mark.parametrize("wl_label,sched_rename",
                             [("max_contention", "pinned"),
                              ("x", "dedicated2"), ("", "y")])
    def test_key_ignores_labels(wl_label, sched_rename):
        _check_label_invariance(wl_label, sched_rename)


def _check_seed_sensitivity(seeds_a, seeds_b):
    ka, kb = _cell_key(seeds=seeds_a), _cell_key(seeds=seeds_b)
    assert (ka == kb) == (tuple(seeds_a) == tuple(seeds_b))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 2**20), min_size=1, max_size=4),
           st.lists(st.integers(0, 2**20), min_size=1, max_size=4))
    def test_key_seed_sensitivity(seeds_a, seeds_b):
        _check_seed_sensitivity(seeds_a, seeds_b)
else:
    @pytest.mark.parametrize("seeds_a,seeds_b",
                             [((0,), (0,)), ((0,), (1,)),
                              ((0, 1), (1, 0)), ((3, 3), (3,))])
    def test_key_seed_sensitivity(seeds_a, seeds_b):
        _check_seed_sensitivity(seeds_a, seeds_b)


# Three ticket-lock authors: A and B are the same algorithm with every
# step, memory word and docstring renamed; C changes one FAA delta.

def _ticket_a(s):
    tk, gr = s.word("ticket"), s.word("grant")

    @s.step("doorway")
    def take(c):
        """Grab the next ticket."""
        return c.op(FAA(tk, 1))

    @s.step("doorway")
    def wait(c):
        return c.op(SPIN_EQ(gr, c.res), arrive=True)

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def bump(c):
        return c.op(LOAD(gr))

    @s.step("release")
    def done(c):
        return c.op(STORE(gr, c.res + 1), to=NCS)


def _ticket_b(s):
    serving, now = s.word("serving_counter"), s.word("now_serving")

    @s.step("doorway")
    def acquire_ticket(c):
        """Completely different prose, same semantics."""
        return c.op(FAA(serving, 1))

    @s.step("doorway")
    def spin_on_grant(c):
        return c.op(SPIN_EQ(now, c.res), arrive=True)

    @s.step("entry")
    def admitted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def read_grant(c):
        return c.op(LOAD(now))

    @s.step("release")
    def publish_next(c):
        return c.op(STORE(now, c.res + 1), to=NCS)


def _ticket_c(s):
    tk, gr = s.word("ticket"), s.word("grant")

    @s.step("doorway")
    def take(c):
        return c.op(FAA(tk, 2))      # semantic change: stride-2 tickets

    @s.step("doorway")
    def wait(c):
        return c.op(SPIN_EQ(gr, c.res), arrive=True)

    @s.step("entry")
    def granted(c):
        return c.enter_cs(admit=True)

    @s.step("release")
    def bump(c):
        return c.op(LOAD(gr))

    @s.step("release")
    def done(c):
        return c.op(STORE(gr, c.res + 1), to=NCS)


def test_fingerprint_ignores_labels_catches_semantics():
    fa = cachemod.program_fingerprint(compile_spec(_ticket_a, 4))
    fb = cachemod.program_fingerprint(compile_spec(_ticket_b, 4))
    fc = cachemod.program_fingerprint(compile_spec(_ticket_c, 4))
    assert fa == fb      # renames + docstrings are invisible
    assert fa != fc      # one constant differs -> new fingerprint


def test_fingerprint_distinguishes_zoo():
    fps = {lock: cachemod.program_fingerprint(
               SimEngine(lock, n_threads=4).program(4, WL))
           for lock in DIFF_LOCKS}
    assert len(set(fps.values())) == len(DIFF_LOCKS)


_KEY_SCRIPT = r"""
import json
from repro.bench import cache as cachemod
from repro.core.sim.engine import (
    SimEngine, Workload, _lower_host, _lower_sched_host,
)
eng = SimEngine("mcs", n_threads=4)
wl = Workload(0, True, 500)
prog = eng.program(4, wl)
fp = cachemod.program_fingerprint(prog)
key = cachemod.cell_key(fp, 4, wl, _lower_host("smp:4", 4),
                        _lower_sched_host("fair-2x", 4), (0, 1))
print(json.dumps({"fp": fp, "key": key,
                  "parts": cachemod._handler_digests(prog)}))
"""


def test_key_stable_across_processes():
    """The key must be a pure content hash: a fresh interpreter derives
    the same fingerprint and cell key as this one. Regression: the
    fingerprint once hashed ``str(jaxpr)``, whose sub-jaxpr inlining
    depends on jax's process-wide trace caches (a warmed ``_where``
    cache prints as ``jaxpr=_where``), so the in-process value drifted
    mid-session away from what fresh interpreters compute."""
    eng = SimEngine("mcs", n_threads=4)
    wl = Workload(0, True, 500)
    prog = eng.program(4, wl)
    fp = cachemod.program_fingerprint(prog)
    key = cachemod.cell_key(fp, 4, wl, _lower_host("smp:4", 4),
                            _lower_sched_host("fair-2x", 4), (0, 1))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", _KEY_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env=env, cwd=REPO)
    assert p.returncode == 0, p.stderr[-2000:]
    other = json.loads(p.stdout.strip().splitlines()[-1])
    here = {"fp": fp, "key": key,
            "parts": cachemod._handler_digests(prog)}
    diffs = [i for i, (a, b) in enumerate(zip(here["parts"],
                                              other["parts"])) if a != b]
    assert other == here, f"handlers differing: {diffs}"


def test_result_roundtrip_preserves_dtypes():
    r = SimEngine("reciprocating", n_threads=4, workload=WL).run(0)
    back = cachemod.result_from_doc(
        json.loads(json.dumps(cachemod.result_to_doc(r))))
    assert_results_identical(r, back, ctx="json roundtrip")


# --- compile accounting ------------------------------------------------------

def test_two_suite_session_exact_compiles():
    """Regression: one session serving two suites with different
    scheduler stacks. Each new batch shape is exactly one trace; the
    per-session counter and the process-wide ``trace_count()`` agree —
    until a throwaway engine re-traces, which only the process-wide
    counter sees (the historical under-count in suite accounting)."""
    wl = Workload(0, True, 400)
    t0 = trace_count()
    eng = SimEngine("hemlock", n_threads=4, workload=wl)
    # suite 1: topology grid (4-point batch), dedicated scheduler
    g1 = eng.grid(seeds=SEEDS, topologies=["smp:4", "numa:2x2"])
    assert g1.compiles == 1
    # suite 2, same session: 3-scheduler stack -> 6-point batch shape
    g2 = eng.grid(seeds=SEEDS,
                  schedulers=["dedicated", "fair-2x", "fair-4x"])
    assert g2.compiles == 1
    # re-running the wider stack is free: schedulers are data
    g3 = eng.grid(seeds=SEEDS,
                  schedulers=["dedicated", "fair-2x", "fair-4x"])
    assert g3.compiles == 0
    assert eng.compiles == 2
    assert trace_count() - t0 == 2
    # a fresh engine for the same lock re-traces: invisible to any
    # session counter, visible to the process-wide one
    eng2 = SimEngine("hemlock", n_threads=4, workload=wl)
    eng2.grid(seeds=SEEDS, topologies=["smp:4", "numa:2x2"])
    assert eng.compiles == 2
    assert eng2.compiles == 1
    assert trace_count() - t0 == 3


def test_shard_toggle_never_reuses_wrong_jit():
    """The shard count is part of the jit key: toggling modes on one
    session retraces rather than reusing the other path's executable."""
    eng = SimEngine("ticket", n_threads=4, workload=WL)
    eng.grid(seeds=SEEDS, shard=False)
    assert eng.compiles == 1
    eng.grid(seeds=SEEDS, shard=True)
    assert eng.compiles == 2
    eng.grid(seeds=SEEDS, shard=False)
    eng.grid(seeds=SEEDS, shard=True)
    assert eng.compiles == 2      # both paths now cached


# --- harness block + trend log -----------------------------------------------

def test_run_suite_harness_block(own_cache):
    from repro.bench import run_suite
    cfg = BenchConfig(threads=(2,), n_steps=250, n_replicas=1,
                      verbose=False, quick=True)
    doc = run_suite("fairness", cfg)
    h = doc["harness"]
    assert set(h) >= {"wall_s", "xla_traces", "cache_hits",
                      "cache_misses", "cache_stores", "cache_hit_rate"}
    assert h["wall_s"] >= 0
    assert schema.validate_result(doc) == []


def test_trend_append_and_tolerant_load(tmp_path, own_cache):
    from repro.bench import run_suite
    cfg = BenchConfig(threads=(2,), n_steps=250, n_replicas=1,
                      verbose=False, quick=True)
    doc = run_suite("fairness", cfg)
    path = str(tmp_path / "trend.json")
    schema.append_trend(path, schema.trend_entry(doc))
    schema.append_trend(path, schema.trend_entry(doc))
    trend = schema.load_trend(path)
    assert trend["schema"] == schema.TREND_SCHEMA_VERSION
    assert len(trend["entries"]) == 2
    e = trend["entries"][0]
    assert e["suite"] == "fairness"
    assert e["quick"] is True
    assert e["wall_s"] == doc["harness"]["wall_s"]
    assert e["experiments"] == len(doc["experiments"])
    # a corrupt trend file restarts the log instead of failing the run
    with open(path, "w") as f:
        f.write("{not json")
    assert schema.load_trend(path)["entries"] == []


def test_cli_run_emits_trend(tmp_path):
    from repro.bench.cli import main
    prev = cachemod._CACHE
    try:
        out = tmp_path / "r.json"
        rc = main(["run", "--suite", "fairness", "--out", str(out),
                   "--quick", "--no-progress",
                   "--cache-dir", str(tmp_path / "cache")])
        assert rc == 0
        doc = json.loads(out.read_text())
        assert "harness" in doc
        trend = json.loads((tmp_path / "BENCH_trend.json").read_text())
        assert trend["schema"] == schema.TREND_SCHEMA_VERSION
        assert trend["entries"][-1]["suite"] == "fairness"
    finally:
        cachemod._CACHE = prev


def test_cli_list_cache_status(tmp_path, capsys):
    from repro.bench.cli import main
    prev = cachemod._CACHE
    try:
        cachemod.configure(root=str(tmp_path / "cache"))
        rc = main(["list", "--cache",
                   "--trend", str(tmp_path / "trend.json")])
        assert rc == 0
        out = capsys.readouterr().out
        assert "experiment cache" in out
        assert "entries" in out
    finally:
        cachemod._CACHE = prev
