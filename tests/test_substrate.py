"""Substrate tests: runtime locks, data pipeline, checkpointing, fault
tolerance, gradient compression, elastic relayout, admission policies and
the serving scheduler/engine."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property tests degrade to fixed parametrization
    HAVE_HYPOTHESIS = False

from repro.core.admission import POLICIES, ReciprocatingQueue
from repro.core.runtime.reciprocating import ReciprocatingLock
from repro.serve.scheduler import ContinuousBatcher, Request
from repro.sharding.ctx import trivial_ctx


# ---------------------------------------------------------------------------
# runtime lock (real threads)
# ---------------------------------------------------------------------------
def test_runtime_lock_counter():
    lock = ReciprocatingLock()
    counter = {"v": 0}

    def worker():
        for _ in range(300):
            with lock:
                v = counter["v"]
                counter["v"] = v + 1

    ts = [threading.Thread(target=worker) for _ in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert counter["v"] == 8 * 300          # no lost updates
    assert not lock.locked_hint()


def test_runtime_lock_plural_locks_one_element():
    """A thread may hold several locks at once with its single TLS wait
    element (paper's plural-locking requirement), and release in non-LIFO
    order."""
    l1, l2 = ReciprocatingLock(), ReciprocatingLock()
    order = []

    def worker(n):
        for _ in range(50):
            l1.acquire()
            l2.acquire()
            order.append(n)
            l1.release()       # non-LIFO (imbalanced) release order
            l2.release()

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert len(order) == 200


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_pipeline_restartable():
    from repro.data.pipeline import DataConfig, DataPipeline
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2,
                     n_workers=3)
    p = DataPipeline(cfg).start()
    seen = [p.next_batch() for _ in range(6)]
    assert all(b is not None for b in seen)
    chunk_ids = {b["chunk_id"] for b in seen}
    assert len(chunk_ids) == 6              # cursor never double-issues
    state = p.checkpoint_state()
    p.stop()
    # restart from cursor: new chunks continue past the checkpoint
    p2 = DataPipeline(cfg)
    p2.restore(state)
    p2.start()
    b = p2.next_batch()
    assert b["chunk_id"] >= min(chunk_ids)
    p2.stop()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                        save_checkpoint)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.asarray(7)}}
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    zero = jax.tree.map(jnp.zeros_like, state)
    restored, manifest = restore_checkpoint(str(tmp_path), 7, zero)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))


def test_checkpoint_atomic_and_retention(tmp_path):
    from repro.train.checkpoint import latest_step, save_checkpoint
    state = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, state, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_4", "step_5"]
    assert latest_step(str(tmp_path)) == 5


def test_async_checkpointer(tmp_path):
    from repro.train.checkpoint import AsyncCheckpointer, latest_step
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(3, {"w": jnp.ones((8,))})
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    ck.emergency(4, {"w": jnp.ones((8,))})
    assert latest_step(str(tmp_path)) == 4


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_heartbeat_straggler_detection():
    from repro.train.fault_tolerance import HeartbeatMonitor
    hb = HeartbeatMonitor(n_hosts=4, straggler_factor=2.0, dead_after=50.0)
    t = 0.0
    for step in range(5):
        for h in range(4):
            if h == 3 and step >= 3:
                continue                     # host 3 stalls after step 2
            hb.beat(h, step, now=t + h * 0.01)
        t += 1.0
    assert hb.stragglers(now=t + 5.0) == [3]
    assert hb.dead(now=t + 100.0) == [0, 1, 2, 3]


def test_step_guard():
    from repro.train.fault_tolerance import StepGuard
    with StepGuard(5.0):
        pass                                 # fast step: fine
    with pytest.raises(StepGuard.Hang), StepGuard(0.05):
        time.sleep(0.2)


def test_restart_policy_backoff():
    from repro.train.fault_tolerance import RestartPolicy
    rp = RestartPolicy(max_restarts=3, backoff_base=1.0)
    delays = [rp.next_delay() for _ in range(4)]
    assert delays[:3] == [1.0, 2.0, 4.0] and delays[3] is None


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_compressed_allreduce_error_feedback():
    from repro.train.compression import compressed_allreduce, init_residuals
    ctx = trivial_ctx()     # data axis of size 1: psum degenerates, but the
    # quantization + error-feedback math is exercised end to end
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1, 64, 64))}
    res = init_residuals(g)
    acc = jnp.zeros((1, 64, 64))
    exact = jnp.zeros((1, 64, 64))
    for _ in range(8):
        out, res = compressed_allreduce(g, res, ctx)
        acc = acc + out["w"]
        exact = exact + g["w"]
    # error feedback: accumulated compressed mean converges to exact
    rel = float(jnp.abs(acc - exact).max() / jnp.abs(exact).max())
    assert rel < 0.01, rel


# ---------------------------------------------------------------------------
# elastic MoE relayout
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    def _relayout_cases(f):
        return settings(max_examples=10, deadline=None)(
            given(m1=st.sampled_from([1, 2, 4, 8, 16]),
                  m2=st.sampled_from([1, 2, 4, 8, 16]))(f))
else:
    _relayout_cases = pytest.mark.parametrize(
        "m1,m2", [(1, 2), (2, 4), (4, 8), (8, 16), (16, 1), (4, 4)])


@_relayout_cases
def test_moe_relayout_roundtrip(m1, m2):
    from repro.models.layers import moe_topology
    from repro.train.elastic import relayout_moe
    E, D, F = 8, 12, 16
    ep1, tpi1, el1 = moe_topology(E, m1)
    rng = np.random.default_rng(0)
    w1 = rng.normal(size=(m1, el1, D, F // tpi1)).astype(np.float32)
    w2 = relayout_moe(w1, E, m1, m2, down_proj=False)
    back = relayout_moe(w2, E, m2, m1, down_proj=False)
    np.testing.assert_array_equal(w1, back)


# ---------------------------------------------------------------------------
# admission + scheduler
# ---------------------------------------------------------------------------
def test_reciprocating_queue_segments():
    q = ReciprocatingQueue()
    for i in range(4):
        q.push(i)
    assert q.pop() == 3                     # LIFO within segment
    q.push(9)                                # new arrival -> NEXT segment
    assert [q.pop(), q.pop(), q.pop()] == [2, 1, 0]   # current seg first
    assert q.pop() == 9                     # FIFO across segments
    assert q.pop() is None


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_scheduler_completes(policy):
    sched = ContinuousBatcher(policy=policy, max_batch=4, pool_blocks=128)
    rng = np.random.default_rng(1)
    t = 0.0
    for i in range(60):
        t += float(rng.exponential(0.5))
        sched.submit(Request(rid=i, arrival=t, prefix_id=i % 4,
                             prefix_blocks=8, prompt_blocks=2,
                             decode_tokens=6))
    sched.drain()
    s = sched.stats.summary()
    assert s["n"] == 60


def test_reciprocating_scheduling_tradeoff():
    """App. C adaptation (multi-turn regime, ~0.9 utilization, bursty
    shared-prefix arrivals): reciprocating admission captures most of
    LIFO's prefix-cache benefit while bounding the tail wait (bounded
    bypass); raw LIFO starves its tail."""
    import sys
    sys.path.insert(0, ".")
    from benchmarks.scheduler_bench import drive

    fifo = drive("fifo", seed=1)
    rec = drive("reciprocating", seed=1)
    lifo = drive("lifo", seed=1)
    assert rec["prefix_hit_rate"] >= fifo["prefix_hit_rate"] - 0.01
    assert lifo["prefix_hit_rate"] >= rec["prefix_hit_rate"] - 0.01
    # bounded bypass: reciprocating's worst wait is far below LIFO's
    assert rec["max_wait"] < lifo["max_wait"]


def test_inference_engine_end_to_end():
    from repro.configs import get_config, smoke_config
    from repro.models import model as M_
    from repro.serve.engine import GenRequest, InferenceEngine
    cfg = smoke_config(get_config("granite-3-2b"))
    params = M_.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_batch=2)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(GenRequest(rid=i, tokens=rng.integers(
            1, 97, 8, dtype=np.int32), max_new=4))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)
    assert all(0 <= t < cfg.padded_vocab for r in done for t in r.out)
