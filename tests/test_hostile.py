"""Hostile-OS property harness: who degrades gracefully under preemption.

The scheduler layer (``core/sim/sched.py`` lowered into the machine
stepper, DESIGN.md §L1 "Scheduler model") turns the simulator's
dedicated machine into an adversarial OS: finite timeslices, seeded
preemption jitter, oversubscription gaps, and a lock-holder-preemption
bias. These tests drive *random* scheduler configurations (hypothesis
when available, pinned parametrization otherwise) and assert the
invariants that must survive arbitrary descheduling:

* mutual exclusion and progress       (every lock in ``PROGRAMS``)
* no lost wakeups: parking locks keep completing episodes even when
  wakers are descheduled mid-handoff
* the reciprocating family's admission-interleave bound <= 2 (paper §2)
  holds under preemption — descheduling stretches time but cannot
  reorder admissions past the bound
* abort-path integrity for the timed-wait locks: an aborted waiter
  never retains a live queue claim (reciprocating_abortable's baton
  cells stay single-baton; progress continues through abort storms)
* the degenerate scheduler (infinite quantum, cores >= threads, no
  jitter) is *bit-identical* to the schedulerless path — state for
  state — so every pre-scheduler result in docs/RESULTS.md is untouched
* ``spin_then_park``'s unpark accounting: the wake cost lands on the
  *waker's* timeline, pinned by seed either way
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property tests degrade to fixed parametrization
    HAVE_HYPOTHESIS = False

from repro.core.locks.programs import ABORTABLE_VARIANTS, PROGRAMS
from repro.core.sim.machine import (
    CostModel, LoweredSched, run_machine,
)
from repro.core.sim.api import admission_bypass_bound
from repro.core.sim.sched import Scheduler, resolve

ALL = sorted(PROGRAMS)
RECIP_FAMILY = ["reciprocating", "retrograde"]
#: reciprocating_abortable's grant-baton cells: first DSL array => base 8
#: (``dsl.ELEM_BASE``), one word per ticket residue.
CELLS_BASE = 8


@functools.lru_cache(maxsize=None)
def _runner(name: str, T: int, n_steps: int, ncs: int):
    """One jitted (seed, sched-scalars) -> MachineState executor per
    (lock, threads, steps) shape, so hypothesis examples share a trace:
    scheduler parameters are vmap-style *data*, exactly as in the
    engine's batching contract."""
    prog = PROGRAMS[name](T, ncs_max=ncs, cs_shared=True)

    def go(seed, q, lq, co, ji):
        return run_machine(prog, T, n_steps, CostModel(), seed,
                           LoweredSched(q, lq, co, ji))
    return jax.jit(go)


def hostile_state(name, T, seed, sch, n_steps=8000, ncs=2):
    return _runner(name, T, n_steps, ncs)(seed, *sch.lower(T))


def make_sched(quantum, oversub, lhp, jitter) -> Scheduler:
    return Scheduler(name="rand", quantum=quantum, oversub=oversub,
                     lhp_quantum=lhp, jitter=jitter)


# Pinned hostile schedules used when hypothesis is unavailable — chosen
# to hit each axis: bare timeslicing, oversubscription, LHP bias, jitter.
PINNED = [
    (0, 2500, 1.0, None, 0),
    (7, 1200, 2.0, None, 500),
    (3, 800, 4.0, 200, 400),
    (11, 4000, 2.0, 600, 0),
]

if HAVE_HYPOTHESIS:
    def _hostile_cases(f):
        return settings(max_examples=6, deadline=None)(
            given(seed=st.integers(0, 10_000),
                  quantum=st.integers(300, 6000),
                  oversub=st.sampled_from([1.0, 2.0, 4.0]),
                  lhp=st.none() | st.integers(150, 1500),
                  jitter=st.integers(0, 800))(f))
else:
    _hostile_cases = pytest.mark.parametrize(
        "seed,quantum,oversub,lhp,jitter", PINNED)


# --- mutual exclusion / progress under random preemption ---------------------

@pytest.mark.parametrize("name", ALL)
@_hostile_cases
def test_mutual_exclusion_under_preemption(name, seed, quantum, oversub,
                                           lhp, jitter):
    """The CS read-modify-write word stays consistent: each episode
    performs one LOAD/STORE increment on ``mem[4]``, so any ME violation
    under a hostile schedule shows up as a lost or duplicated update
    (a final thread may be frozen mid-CS, hence the +-T slack)."""
    T = 4
    s = hostile_state(name, T, seed, make_sched(quantum, oversub, lhp,
                                                jitter))
    eps = int(np.asarray(s.episodes).sum())
    cs = int(np.asarray(s.mem)[4])
    assert eps > 0, f"{name}: no progress under hostile schedule"
    assert eps - T <= cs <= eps + T, (
        f"{name}: CS word {cs} vs episodes {eps} — mutual exclusion "
        f"violated under quantum={quantum} oversub={oversub} lhp={lhp}")


@pytest.mark.parametrize("name", ["spin_then_park", "mcs", "clh",
                                  "hemlock", "mcs_timeout"])
@_hostile_cases
def test_no_lost_wakeups(name, seed, quantum, oversub, lhp, jitter):
    """Parking locks must not wedge when a waker is descheduled between
    publishing the grant and the sleeper's re-dispatch: at the horizon
    no thread may be parked forever while the lock is free. Sustained
    episode flow across the whole run is the observable: a lost wakeup
    freezes the system at the loss point."""
    T = 4
    s = hostile_state(name, T, seed, make_sched(quantum, oversub, lhp,
                                                jitter), n_steps=9000)
    eps = np.asarray(s.episodes)
    assert int(eps.sum()) > 0
    # every thread was admitted at least once (no starved sleeper):
    # bounded-bypass and FIFO admission both imply this on a 9000-step
    # horizon even under 4x oversubscription.
    assert int(eps.min()) >= 1, f"{name}: starved thread {eps}"


@pytest.mark.parametrize("name", RECIP_FAMILY)
@_hostile_cases
def test_reciprocating_interleave_bound_under_preemption(
        name, seed, quantum, oversub, lhp, jitter):
    """Paper §2's thread-specific bounded bypass is an *algorithmic*
    property of the admission order: descheduling delays threads but the
    palindromic segment discipline still admits any single peer at most
    twice between consecutive admissions of a waiter (<= 2 on the timed
    machine, see ``admission_bypass_bound``)."""
    T = 4
    s = hostile_state(name, T, seed, make_sched(quantum, oversub, lhp,
                                                jitter), n_steps=10_000)
    bound = admission_bypass_bound(np.asarray(s.adm_log)[None, :],
                                   np.asarray(s.adm_cnt)[None])
    assert bound <= 2, f"{name}: interleave bound {bound} under preemption"


# --- abort-path invariants ---------------------------------------------------

@pytest.mark.parametrize("name", sorted(ABORTABLE_VARIANTS))
@_hostile_cases
def test_abortable_me_and_progress(name, seed, quantum, oversub, lhp,
                                   jitter):
    """The timed-wait variants keep ME and progress while aborts fire."""
    T = 4
    s = hostile_state(name, T, seed, make_sched(quantum, oversub, lhp,
                                                jitter), n_steps=10_000)
    eps = int(np.asarray(s.episodes).sum())
    cs = int(np.asarray(s.mem)[4])
    assert eps > 0
    assert eps - T <= cs <= eps + T, f"{name}: ME violated with aborts"


@_hostile_cases
def test_aborted_waiter_retains_no_queue_cell(seed, quantum, oversub,
                                              lhp, jitter):
    """reciprocating_abortable's abort path must leave the grant cells
    coherent: at any horizon there is at most ONE live baton (tag
    ``v % 4 == 1``) across the cells — an aborted waiter's residue is a
    marker (tag 2) or zero, never a retained claim that could admit it
    later. A second live baton would mean an aborted waiter kept its
    cell and the single-baton mutual-exclusion argument collapses."""
    T = 8
    s = hostile_state("reciprocating_abortable", T, seed,
                      make_sched(quantum, oversub, lhp, jitter),
                      n_steps=12_000)
    cells = np.asarray(s.mem)[CELLS_BASE:CELLS_BASE + T]
    batons = int((cells % 4 == 1).sum())
    markers = int((cells % 4 == 2).sum())
    assert batons <= 1, f"multiple live batons: cells={cells}"
    assert markers <= T, f"marker leak: cells={cells}"
    assert int(np.asarray(s.episodes).sum()) > 0


def test_aborts_fire_under_pressure():
    """Pinned sanity: a harsh schedule actually exercises the abort path
    (timeouts expire, waiters bail to the NCS), and the abort metric
    ``returns - episodes`` counts them."""
    sch = Scheduler(name="nasty", quantum=800, oversub=4.0,
                    lhp_quantum=200, jitter=400)
    s = hostile_state("reciprocating_abortable", 8, 0, sch,
                      n_steps=20_000, ncs=0)
    eps = int(np.asarray(s.episodes).sum())
    aborts = int(np.asarray(s.returns).sum()) - eps
    assert eps > 0 and aborts > 0, (eps, aborts)
    # and the dedicated machine keeps aborts low for mcs_timeout, whose
    # patience spans an uncontended handoff comfortably
    s2 = hostile_state("mcs_timeout", 4, 0, resolve("dedicated"),
                       n_steps=12_000, ncs=0)
    eps2 = int(np.asarray(s2.episodes).sum())
    assert eps2 > 0
    assert int(np.asarray(s2.returns).sum()) - eps2 <= 1


# --- degenerate scheduler: bit-identical to the schedulerless path -----------

@pytest.mark.parametrize("name", ALL)
def test_degenerate_scheduler_bit_identical(name):
    """quantum=inf, cores >= threads, jitter=0, aborts never firing =>
    the scheduler terms vanish algebraically and the machine must
    produce the *same MachineState, field for field*, as the
    schedulerless path. This pins the claim that pre-scheduler results
    are untouched (and that ``lower_sched(None)`` is the true identity
    element), for every lock in the registry."""
    T, steps = 4, 6000
    prog = PROGRAMS[name](T, ncs_max=2, cs_shared=True)
    degen = Scheduler(name="degen")          # no quantum, oversub 1.0
    for seed in (0, 3):
        s0 = run_machine(prog, T, steps, CostModel(), seed)
        s1 = run_machine(prog, T, steps, CostModel(), seed, degen)
        for f, a, b in zip(s0._fields, s0, s1):
            assert jnp.array_equal(a, b), (
                f"{name} seed {seed}: field {f} diverged under the "
                f"degenerate scheduler")


# --- spin_then_park unpark accounting (pinned regression) --------------------

def test_unpark_charged_to_waker_not_sleeper():
    """The waker pays ``unpark_cost`` on its own timeline (it executes
    the wake syscall); the sleeper resumes at the grant's finish time
    plus only the re-dispatch overhead. Observable: inflating
    unpark_cost must NOT inflate the sleeper's arrive->admit latency by
    the full unpark per contended handoff — sleeper-side accounting
    (the old bug) serializes the wake cost onto every admission's
    critical path."""
    prog = PROGRAMS["spin_then_park"](4, ncs_max=0, cs_shared=True)
    cheap = run_machine(prog, 4, 8000, CostModel(unpark_cost=0), 7)
    dear = run_machine(prog, 4, 8000, CostModel(unpark_cost=900), 7)
    lat = lambda s: (int(np.asarray(s.lat_sum).sum())
                     / max(int(np.asarray(s.episodes).sum()), 1))
    assert lat(dear) < lat(cheap) + 900, (lat(cheap), lat(dear))


def test_spin_then_park_pinned_seed_regression():
    """Pin the post-fix behavior by seed, both regimes: default costs
    (heavy spinning saturates the 20-cycle recheck cadence) and an
    expensive-unpark machine (wakers lag on their own timelines, the
    lock relays through long sleeps). Sleeper-side accounting shifts
    every one of these numbers."""
    prog = PROGRAMS["spin_then_park"](4, ncs_max=0, cs_shared=True)
    s = run_machine(prog, 4, 8000, CostModel(), 7)
    assert int(s.time) == 160_000
    assert np.asarray(s.episodes).tolist() == [111, 111, 111, 111]
    d = run_machine(prog, 4, 8000, CostModel(unpark_cost=900), 7)
    assert np.asarray(d.episodes).tolist() == [223, 224, 24, 341]
