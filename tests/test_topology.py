"""Topology model + ``SimEngine`` session API tests.

* **Structure** — preset trees, cost/remote matrices, placement,
  shorthand resolution.
* **Migration oracle** — flat-``CostModel`` results are *frozen* against
  goldens captured from the pre-redesign machine (pinned seeds), and a
  degenerate single-level topology is bit-identical to the flat path,
  state field for state field (mirrors PR 3's differential-oracle
  pattern: the redesign re-plumbs execution, never the numbers).
* **Batching** — one XLA trace per (threads, workload) grid shape and
  zero for repeats: the compile-count assertion CI relies on, so a
  regression that silently recompiles per topology fails loudly.
* **Shims** — ``run_ensemble`` / ``sweep_threads`` / ``run_grid``
  deprecation forwards, including the ``dataclasses.replace`` semantics
  that keep newly added ``CostModel`` fields alive through ``run_grid``.
"""
import dataclasses

import numpy as np
import pytest

from repro.bench.sweep import run_grid
from repro.core.locks.programs import PROGRAMS
from repro.core.sim.api import bench_lock
from repro.core.sim.engine import (
    WORKLOADS, SimEngine, Workload, cost_label,
)
from repro.core.sim.machine import (
    CostModel, lower_cost, run_ensemble, run_machine,
)
from repro.core.sim.topology import PRESETS, ccx, numa, resolve, smp

STATE_FIELDS = ("mem", "owner", "sharers", "last_writer", "pc", "regs",
                "time", "episodes", "misses", "remote", "inval_recv",
                "lat_sum", "adm_log", "adm_cnt")

# --- structure ---------------------------------------------------------------


def test_cost_matrix_numa():
    t = numa(2, 4, local=40, remote=100)
    m, r = t.cost_matrix(8), t.remote_matrix(8)
    assert m.shape == (8, 8) and np.array_equal(m, m.T)
    assert (m[np.arange(8), np.arange(8)] == 40).all()   # own home: local
    assert m[0, 3] == 40 and not r[0, 3]                 # same node
    assert m[0, 4] == 100 and r[0, 4]                    # cross node
    assert r.sum() == 2 * 4 * 4                          # 2 off-node blocks


def test_cost_matrix_ccx_three_tiers():
    t = ccx(sockets=2, ccx_per_socket=2, per_ccx=4,
            ccx_cost=25, socket_cost=60, cross_cost=140)
    m, r = t.cost_matrix(16), t.remote_matrix(16)
    assert m[0, 1] == 25 and not r[0, 1]      # same CCX
    assert m[0, 5] == 60 and not r[0, 5]      # same socket, other CCX
    assert m[0, 9] == 140 and r[0, 9]         # cross socket: NUMA-remote
    assert sorted(set(m.flatten().tolist())) == [25, 60, 140]


def test_placement_interleave():
    t = numa(2, 4)
    ti = t.interleave()
    assert ti.name.endswith("+interleave")
    # contiguous: threads 0,1 share node 0; interleaved: they split
    assert not t.remote_matrix(8)[0, 1]
    assert ti.remote_matrix(8)[0, 1]
    # interleave is a permutation of the same machine
    assert sorted(ti.leaves(8).tolist()) == list(range(8))
    assert np.sort(ti.cost_matrix(8), axis=None).tolist() == \
        np.sort(t.cost_matrix(8), axis=None).tolist()


def test_resolve_and_presets():
    assert resolve("epyc-2s") is PRESETS["epyc-2s"]
    assert resolve("smp:6").n_leaves == 6
    assert resolve("numa:4x2").n_leaves == 8
    assert resolve("ccx:4x2x2").n_leaves == 16
    assert resolve("ccx").name == ccx().name
    assert resolve(smp(3)).n_leaves == 3
    with pytest.raises(KeyError):
        resolve("hypercube")
    with pytest.raises(KeyError):
        resolve("ccx:4x4")      # malformed shorthand must not be ignored
    for t in PRESETS.values():
        assert t.levels[-1].remote     # every preset has a NUMA boundary


def test_oversubscription_raises():
    with pytest.raises(ValueError):
        smp(4).cost_matrix(8)
    with pytest.raises(ValueError):
        SimEngine("mcs", topology=numa(2, 2), n_threads=6).run(0)


def test_flat_lowering_matches_equivalent_topology():
    lc_flat = lower_cost(CostModel(n_nodes=2), 8)
    lc_topo = lower_cost(numa(2, 4), 8)
    assert np.array_equal(np.asarray(lc_flat.miss),
                          np.asarray(lc_topo.miss))
    assert np.array_equal(np.asarray(lc_flat.remote),
                          np.asarray(lc_topo.remote))


# --- migration oracle --------------------------------------------------------

# Pre-redesign goldens: (throughput, episodes, miss/ep, latency,
# per-replica bus time) from the seed machine's flat branch — T=6,
# 4000 steps, seeds (0, 1), max contention, shared-rw CS.
GOLD = {
    ("reciprocating", 1): (4.158806755867515, 948, 6.006329113924051,
                           1098.3544303797469, [113975, 113975]),
    ("reciprocating", 2): (2.6847158109371017, 948, 6.006329113924051,
                           1693.0379746835442, [176555, 176555]),
    ("ticket", 1): (2.764547180494954, 664, 9.018072289156626,
                    1692.6656626506024, [120092, 120092]),
    ("ticket", 2): (1.5829725554517193, 664, 9.018072289156626,
                    2923.027108433735, [209732, 209732]),
    ("mcs", 1): (2.7675559644280896, 722, 9.033240997229917,
                 1635.3434903047091, [130440, 130440]),
    ("mcs", 2): (2.034719873745914, 722, 9.033240997229917,
                 2220.7174515235456, [177420, 177420]),
}
ORACLE_WL = Workload(ncs_max=0, cs=True, n_steps=4000)


@pytest.mark.parametrize("name,nodes", sorted(GOLD))
def test_flat_results_frozen_to_pre_redesign(name, nodes):
    """The engine's flat path reproduces the pre-topology machine
    bit-for-bit (float metrics compared exactly: the underlying state is
    integer, so the derived doubles are deterministic)."""
    thr, eps, miss, lat, times = GOLD[(name, nodes)]
    eng = SimEngine(name, topology=CostModel(n_nodes=nodes), n_threads=6,
                    workload=ORACLE_WL)
    r = eng.ensemble([0, 1])
    assert (r.throughput, r.episodes, r.miss_per_episode, r.latency) \
        == (thr, eps, miss, lat)
    st = eng.states([0, 1])
    assert [int(t) for t in np.asarray(st.time)] == times


@pytest.mark.parametrize("name", ["reciprocating", "ticket", "mcs",
                                  "hapax", "ttas"])
def test_degenerate_topology_bit_identical_to_flat(name):
    """Satellite invariant: on a single-level topology every lock's full
    machine state — and hence its BenchResult — equals the flat
    ``CostModel`` path exactly."""
    eng = SimEngine(name, n_threads=6, workload=ORACLE_WL)
    flat = eng.states([0, 1], topology=CostModel(n_nodes=1))
    topo = eng.states([0, 1], topology=smp(6))
    for f in STATE_FIELDS:
        assert np.array_equal(np.asarray(getattr(flat, f)),
                              np.asarray(getattr(topo, f))), (name, f)
    # and the 2-node NUMA machine equals its topology-tree spelling
    flat2 = eng.states([0, 1], topology=CostModel(n_nodes=2))
    topo2 = eng.states([0, 1], topology=numa(2, 3))
    for f in STATE_FIELDS:
        assert np.array_equal(np.asarray(getattr(flat2, f)),
                              np.asarray(getattr(topo2, f))), (name, f)


# --- engine API --------------------------------------------------------------

def test_engine_ensemble_matches_grid_cell():
    eng = SimEngine("reciprocating", n_threads=4,
                    workload=Workload(n_steps=2000))
    r = eng.ensemble([0, 1], topology=numa(2, 2))
    g = eng.grid(seeds=[0, 1], topologies=[numa(2, 2)])
    c = g.cell(topology="numa2x2")
    assert (c.result.throughput, c.result.episodes,
            c.result.miss_per_episode) == \
        (r.throughput, r.episodes, r.miss_per_episode)
    assert c.lock == "reciprocating" and c.n_threads == 4


def test_grid_axes_cross_product():
    eng = SimEngine("mcs", n_threads=4, workload=Workload(n_steps=1000))
    g = eng.grid(seeds=[0], topologies=[smp(8), "numa:2x4"],
                 workloads=["max_contention", "readonly"],
                 threads=[2, 4])
    assert len(g) == 2 * 2 * 2
    assert {c.workload for c in g} == {"max_contention", "readonly"}
    assert {c.topology for c in g} == {"smp8", "numa2x4"}
    assert {c.n_threads for c in g} == {2, 4}
    with pytest.raises(KeyError):
        g.cell(topology="smp8")        # ambiguous: 4 cells match


def test_one_jit_per_grid_shape():
    """The batching contract: seed x topology axes never retrace; only a
    new (threads, workload-shape) pair does. A 2-node NUMA grid point
    costs zero extra compiles next to SMP."""
    eng = SimEngine("reciprocating", n_threads=6,
                    workload=Workload(n_steps=800))
    g = eng.grid(seeds=[0, 1],
                 topologies=[smp(6), CostModel(n_nodes=2), numa(3, 2),
                             ccx(2, 1, 3)])
    assert g.compiles == 1
    # same shape again: fully cached
    g2 = eng.grid(seeds=[2, 3],
                  topologies=[numa(2, 3), smp(6), "numa:3x2",
                              CostModel(n_nodes=6)])
    assert g2.compiles == 0
    # a new workload re-traces once; a new thread count likewise
    g3 = eng.grid(seeds=[0, 1], topologies=[smp(6), numa(2, 3),
                                            numa(3, 2), ccx(2, 1, 3)],
                  workloads=["readonly"])
    assert g3.compiles == 1
    assert eng.compiles == 2


def test_workloads_and_labels():
    assert WORKLOADS["readonly"].cs_mode == "ro"
    assert Workload(120, False).name == "local/ncs120"
    assert cost_label(CostModel(n_nodes=2)) == "flat:2"
    assert "park" in cost_label(CostModel(park_cost=0, unpark_cost=0))
    assert cost_label("epyc-2s") == "epyc-2s"
    with pytest.raises(KeyError):
        SimEngine("mcs", workload="turbo")


def test_bench_lock_accepts_topology_and_preset():
    ra = bench_lock("mcs", 6, n_steps=2000, n_replicas=2,
                    cost=CostModel(n_nodes=2))
    rb = bench_lock("mcs", 6, n_steps=2000, n_replicas=2,
                    cost="numa:2x3")
    assert (ra.throughput, ra.episodes) == (rb.throughput, rb.episodes)
    rc = bench_lock("mcs", 6, n_steps=2000, n_replicas=2,
                    cost=PRESETS["epyc-2s"])
    assert rc.episodes > 0


# --- deprecation shims -------------------------------------------------------

def test_run_ensemble_shim_forwards():
    prog = PROGRAMS["ticket"](4, ncs_max=0, cs_shared=True)
    with pytest.deprecated_call():
        s = run_ensemble(prog, 4, 1500, CostModel(n_nodes=1),
                         n_replicas=2, seed0=0)
    direct = run_machine(prog, 4, 1500, CostModel(n_nodes=1), 0)
    assert np.array_equal(np.asarray(s.episodes)[0],
                          np.asarray(direct.episodes))


def test_sweep_threads_shim_forwards():
    from repro.core.sim.api import sweep_threads
    with pytest.deprecated_call():
        out = sweep_threads("ticket", (2, 4), n_steps=1000, n_replicas=1,
                            cost=CostModel(n_nodes=1))
    assert [r.n_threads for r in out] == [2, 4]
    assert all(r.episodes > 0 for r in out)


def test_run_grid_shim_keeps_new_costmodel_fields():
    """The historical bug: run_grid rebuilt the CostModel field by field,
    silently dropping anything newly added. The shim now goes through
    ``dataclasses.replace``, so e.g. park costs survive."""
    prog = PROGRAMS["spin_then_park"](4, ncs_max=0, cs_shared=True)
    base = CostModel(n_nodes=1, park_cost=50, unpark_cost=500)
    with pytest.deprecated_call():
        s = run_grid(prog, 4, 3000, [0, 0], [1, 1], cost=base)
    direct = run_machine(prog, 4, 3000, base, 0)
    assert np.array_equal(np.asarray(s.time)[0], np.asarray(direct.time))
    # and the park costs actually made it through (non-default machine)
    cheap = run_machine(prog, 4, 3000,
                        dataclasses.replace(base, unpark_cost=0), 0)
    assert int(direct.time) != int(cheap.time)
