"""Property tests for the reference lock algorithms (the paper's claims).

* Mutual exclusion under arbitrary interleavings   (all algorithms)
* Strict FIFO for ticket/MCS/CLH/HemLock/Anderson  (paper Table 1)
* Thread-specific bounded bypass <= 1 for Reciprocating / Gated /
  Retrograde (paper §2 / App. G / App. H)
* Table 2: the exact palindromic admission cycle under sustained
  contention, with exactly 2x admission unfairness (paper §9.1/9.2)
* Progress (no deadlock / livelock of the whole system)
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property tests degrade to fixed parametrization
    HAVE_HYPOTHESIS = False

from repro.core.locks.reference import ALGORITHMS
from repro.core.sim.interleave import run

FIFO_ALGS = ["ticket", "mcs", "clh", "hemlock", "anderson"]
BB_ALGS = ["reciprocating", "reciprocating_gated", "retrograde"]
ALL = sorted(ALGORITHMS)


if HAVE_HYPOTHESIS:
    _mx_cases = lambda f: settings(max_examples=20, deadline=None)(
        given(seed=st.integers(0, 10_000), n=st.integers(2, 8),
              ncs=st.integers(0, 3))(f))
    _run_cases = lambda f: settings(max_examples=15, deadline=None)(
        given(seed=st.integers(0, 10_000), n=st.integers(2, 8))(f))
else:
    _mx_cases = pytest.mark.parametrize(
        "seed,n,ncs", [(0, 2, 0), (1, 5, 1), (7, 8, 3), (42, 3, 2)])
    _run_cases = pytest.mark.parametrize(
        "seed,n", [(0, 2), (1, 5), (7, 8), (42, 3)])


@pytest.mark.parametrize("name", ALL)
@_mx_cases
def test_mutual_exclusion_and_progress(name, seed, n, ncs):
    r = run(ALGORITHMS[name](n), n, n_ops=6000, policy="random",
            seed=seed, ncs_ops=ncs)
    # progress: the system as a whole completes episodes
    assert sum(r.episodes.values()) > 0
    # mutual exclusion is asserted inside run() on every CS entry


@pytest.mark.parametrize("name", FIFO_ALGS)
@_run_cases
def test_strict_fifo(name, seed, n):
    r = run(ALGORITHMS[name](n), n, n_ops=8000, policy="random", seed=seed)
    assert r.is_fifo(), f"{name} violated FIFO"
    assert r.max_bypass() == 0


@pytest.mark.parametrize("name", BB_ALGS)
@_run_cases
def test_bounded_bypass(name, seed, n):
    """Paper §2: a later arrival can overtake a waiter at most once before
    the waiter is next admitted."""
    r = run(ALGORITHMS[name](n), n, n_ops=10_000, policy="random", seed=seed)
    assert r.max_bypass() <= 1, f"{name} bypass={r.max_bypass()}"


def test_palindromic_schedule_table2():
    """Paper Table 2: sustained contention with 5 threads settles into the
    8-step palindromic cycle (A once, E once, B/C/D twice — up to thread
    relabeling), i.e. 2x bimodal admission unfairness."""
    r = run(ALGORITHMS["reciprocating"](5), 5, n_ops=8000, policy="rr")
    cyc = r.cycle()
    assert cyc is not None and len(cyc) == 8, f"cycle={cyc}"
    counts = sorted(cyc.count(t) for t in range(5))
    assert counts == [1, 1, 2, 2, 2]          # bimodal: Table 2's structure
    assert abs(r.unfairness() - 2.0) < 0.1    # §9.2 worst-case 2x


def test_retrograde_mimics_reciprocating_admission():
    """App. G: the retrograde ticket lock yields the same admission cycle."""
    r1 = run(ALGORITHMS["reciprocating"](5), 5, n_ops=8000, policy="rr")
    r2 = run(ALGORITHMS["retrograde"](5), 5, n_ops=8000, policy="rr")
    c1, c2 = r1.cycle(), r2.cycle()
    assert c1 is not None and c2 is not None
    # same cycle up to rotation
    assert len(c1) == len(c2)
    doubled = c2 + c2
    assert any(doubled[i:i + len(c1)] == c1 for i in range(len(c2)))


def test_ticket_is_round_robin():
    r = run(ALGORITHMS["ticket"](5), 5, n_ops=8000, policy="rr")
    cyc = r.cycle()
    assert cyc is not None and sorted(cyc) == [0, 1, 2, 3, 4]
    assert r.unfairness() < 1.05


def test_gated_bounded_unfairness():
    """App. H: gated variant's admission differs slightly but long-term
    unfairness stays bounded by 2x."""
    r = run(ALGORITHMS["reciprocating_gated"](5), 5, n_ops=12_000,
            policy="rr")
    assert r.unfairness() <= 2.1


@pytest.mark.parametrize("name", ALL)
def test_single_thread_uncontended(name):
    """Uncontended fast path: a single thread acquires and releases freely."""
    r = run(ALGORITHMS[name](1), 1, n_ops=2000, policy="random", seed=3)
    assert r.episodes[0] > 50
