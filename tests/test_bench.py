"""Tests for the repro.bench harness: schema round-trip, registry
coverage of every lock program, bypass instrumentation bounds, CLI, and a
tiny end-to-end `paper` sweep."""
import json
import os

import pytest

from repro.bench import (
    BenchConfig, SCHEMA_VERSION, load_result, names, run_suite, save_result,
    validate_result,
)
from repro.bench import schema, sweep
from repro.bench.cli import main as cli_main
from repro.bench.report import render_markdown
from repro.bench.suites import FIG1_ALGS
from repro.core.locks.programs import PROGRAMS


def _sample_doc():
    doc = schema.new_result("unit", config={"quick": True})
    doc["experiments"] = [
        schema.sweep_experiment(
            "s", "a sweep", "threads",
            [{"label": "mcs",
              "points": [{"threads": 1, "throughput": 2.5},
                         {"threads": 2, "throughput": 1.5}]}]),
        schema.table_experiment("t", "a table", ["lock", "miss"],
                                [{"lock": "clh", "miss": 5.0}]),
        schema.scalars_experiment("v", "scalars", {"cycle": "ABBA",
                                                   "unfair": 2.0}),
        schema.hist_experiment("h", "hist", ["0", "1", "2+"],
                               [{"label": "fifo", "counts": [10, 0, 0]}]),
    ]
    return doc


def test_schema_roundtrip(tmp_path):
    doc = _sample_doc()
    assert validate_result(doc) == []
    p = str(tmp_path / "r.json")
    save_result(doc, p)
    back = load_result(p)
    assert back == json.loads(json.dumps(doc))   # float-safe equality
    assert back["schema"] == SCHEMA_VERSION


@pytest.mark.parametrize("mutate", [
    lambda d: d.pop("schema"),
    lambda d: d.__setitem__("experiments", "nope"),
    lambda d: d["experiments"][0].__setitem__("kind", "mystery"),
    lambda d: d["experiments"][0]["series"][0]["points"].clear(),
    lambda d: d["experiments"][3]["series"][0].__setitem__("counts", [1]),
    lambda d: d["experiments"].append(dict(d["experiments"][1])),  # dup name
])
def test_schema_rejects_invalid(mutate, tmp_path):
    doc = _sample_doc()
    mutate(doc)
    assert validate_result(doc) != []
    with pytest.raises(ValueError):
        save_result(doc, str(tmp_path / "bad.json"))


def test_registry_exposes_every_lock_program():
    # the paper suite's Fig. 1 sweeps must cover the full program roster
    assert set(FIG1_ALGS) == set(PROGRAMS)
    for suite in ("paper", "mutexbench", "coherence", "fairness",
                  "atomics", "kvstore", "residency", "scheduler",
                  "serve", "kernels", "roofline", "locks-ext",
                  "topology"):
        assert suite in names()


def test_cli_list_programs_and_suites(capsys):
    assert cli_main(["list", "--programs"]) == 0
    out = capsys.readouterr().out
    assert "# lock programs" in out and "# suites" not in out
    for name in PROGRAMS:
        assert name in out
    assert "doorway:" in out and "(new variant)" in out
    # default stays suites-only (backwards compatible)
    assert cli_main(["list"]) == 0
    out = capsys.readouterr().out
    assert "# suites" in out and "# lock programs" not in out
    # both flags => both catalogues
    assert cli_main(["list", "--suites", "--programs"]) == 0
    out = capsys.readouterr().out
    assert "# suites" in out and "# lock programs" in out
    assert "locks-ext" in out


def test_cli_list_properties_matrix(capsys):
    # structural-only verified-property matrix (no model check => fast)
    assert cli_main(["list", "--properties"]) == 0
    out = capsys.readouterr().out
    assert "model_check" in out
    for name in PROGRAMS:
        assert name in out
    assert "✓ own cell" in out          # reciprocating's spin column
    assert "✗ declared shared" in out   # ticket's declared opt-out


def test_locks_ext_suite_tiny():
    doc = run_suite("locks-ext", TINY)
    assert validate_result(doc) == []
    by = {e["name"]: e for e in doc["experiments"]}
    labels = {s["label"] for s in by["locksext_sweep"]["series"]}
    assert {"hapax", "fissile", "spin_then_park"} <= labels
    prof = {r["lock"]: r for r in by["locksext_profile"]["rows"]}
    assert prof["ticket"]["bypass_bound"] <= 2       # FIFO stays bounded
    assert all("spec_steps" in r for r in by["locksext_profile"]["rows"])
    assert len(by["locksext_park"]["rows"]) >= 3
    assert "| lock |" in render_markdown(doc)


def test_topology_suite_tiny():
    doc = run_suite("topology", TINY)
    assert validate_result(doc) == []
    by = {e["name"]: e for e in doc["experiments"]}
    rows = by["topology_grid"]["rows"]
    assert {r["lock"] for r in rows} == set(PROGRAMS)
    machines = {r["topology"] for r in rows}
    assert any(m.startswith("smp") for m in machines)
    assert any(m.startswith("numa") for m in machines)
    assert any(m.startswith("ccx") for m in machines)
    # SMP never produces remote misses; NUMA machines do for queue locks
    for r in rows:
        if r["topology"].startswith("smp"):
            assert r["remote_per_episode"] == 0.0, r
    # the batching contract rides in the document itself
    stats = by["topology_compile"]["values"]
    assert stats["compiles_per_grid"] <= 1.0
    assert by["topology_remote_scaling"]["series"]
    assert {r["placement"] for r in by["topology_placement"]["rows"]} \
        == {"contiguous", "interleaved"}


def test_cli_list_topologies(capsys):
    assert cli_main(["list", "--topologies"]) == 0
    out = capsys.readouterr().out
    assert "# machine topologies" in out and "# suites" not in out
    for name in ("epyc-2s", "xeon-4s", "m2-ultra", "smp:N", "numa:KxP"):
        assert name in out


def test_cli_list_backends(capsys):
    assert cli_main(["list", "--backends"]) == 0
    out = capsys.readouterr().out
    assert "# execution backends" in out and "# suites" not in out
    for name in ("sim", "pallas-interpret", "pallas-device"):
        assert name in out
    # CPU CI: the interpret fallback must probe as available
    assert "pallas-interpret  available" in out


def test_measured_suite_tiny():
    cfg = BenchConfig(threads=(2, 3), n_steps=250, n_replicas=1,
                      verbose=False, quick=True,
                      algs=("reciprocating", "ticket"))
    doc = run_suite("measured", cfg)
    assert validate_result(doc) == []
    by = {e["name"]: e for e in doc["experiments"]}
    backs = {r["name"] for r in by["measured_backends"]["rows"]}
    assert backs == {"sim", "pallas-interpret", "pallas-device"}
    series = {s["label"]: s for s in by["measured_fig1a"]["series"]}
    assert set(series) == {"reciprocating", "ticket"}
    for s in series.values():
        for p in s["points"]:
            assert p["collisions"] == 0
            assert p["episodes"] > 0
    # the agreement gate: both order and CS counts, zero ME violations
    for r in by["measured_agreement"]["rows"]:
        assert r["order_match"] and r["cs_counts_match"], r
        assert r["collisions"] == 0
    fit = by["measured_calibration_fit"]["values"]
    assert fit["scale_kslice_per_kcycle"] > 0
    assert by["measured_calibration"]["rows"]
    assert "measured" in render_markdown(doc)


def test_measured_cells_cache_under_measured_kind():
    """Measured cells are content-addressed under a distinct key kind:
    a second identical call replays from the store, and the key never
    collides with a sim cell of the same program."""
    from repro.bench import cache as cachemod
    from repro.bench.measured import _measured_key, measured_cell
    from repro.core.locks.pallas_backend import resolve_ir
    from repro.core.sim.engine import Workload

    store = cachemod.get_cache()
    if not store.enabled:
        pytest.skip("experiment cache disabled")
    c1 = measured_cell("ticket", 2, 64, seed=11)
    s0 = store.stats.snapshot()
    c2 = measured_cell("ticket", 2, 64, seed=11)
    s1 = store.stats.snapshot()
    assert c2 == c1
    assert s1["hits"] == s0["hits"] + 1
    ir = resolve_ir("ticket", 2)
    key = _measured_key(ir, 2, 64, 11, True)
    fp = cachemod.program_fingerprint(ir)
    assert key != cachemod.cell_key(fp, 2, Workload(0, True, 64),
                                    [], [], [11])


def test_bypass_bounds_match_paper():
    bins, series, stats = sweep.bypass_histograms(
        ("fifo", "lifo", "reciprocating"), n_threads=6, n_events=600)
    by = {r["policy"]: r for r in stats}
    assert by["fifo"]["max_bypass_per_wait"] == 0
    # paper §2: any single later arrival overtakes a waiter at most once
    assert by["reciprocating"]["max_bypass_by_single_thread"] <= 1
    assert by["reciprocating"]["theoretical_single_thread_bound"] == 1
    # raw LIFO starves: a waiter is still outstanding after many bypasses
    assert by["lifo"]["max_outstanding_unserved"] > 100
    labels = [s["label"] for s in series]
    assert labels == ["fifo", "lifo", "reciprocating"]
    assert all(len(s["counts"]) == len(bins) for s in series)


TINY = BenchConfig(threads=(2,), n_steps=250, n_replicas=1, verbose=False,
                   quick=True)


def test_paper_suite_tiny_sweep():
    doc = run_suite("paper", TINY)
    assert validate_result(doc) == []
    by_name = {e["name"]: e for e in doc["experiments"]}
    # per-lock throughput-vs-threads curves for every program
    fig1a = by_name["fig1a_max_contention"]
    assert {s["label"] for s in fig1a["series"]} == set(PROGRAMS)
    for s in fig1a["series"]:
        for p in s["points"]:
            assert p["threads"] == 2
            assert p["throughput"] >= 0
    assert {e["kind"] for e in doc["experiments"]} \
        == {"sweep", "table", "scalars", "hist"}
    # coherence table has one row per Table-1 lock
    assert len(by_name["table1_coherence"]["rows"]) == 8
    # the renderer accepts the real document
    md = render_markdown(doc)
    assert "GENERATED" in md and "fig" not in md.split("\n")[0]
    assert "| lock |" in md


def test_cli_run_report_validate(tmp_path, capsys):
    out = str(tmp_path / "BENCH_residency.json")
    rep = str(tmp_path / "RESULTS.md")
    assert cli_main(["run", "--suite", "residency", "--out", out,
                     "--quick", "--no-progress", "--report", rep]) == 0
    assert os.path.exists(out) and os.path.exists(rep)
    doc = load_result(out)
    assert doc["suite"] == "residency"
    assert cli_main(["validate", "--in", out]) == 0
    # re-render from disk
    rep2 = str(tmp_path / "R2.md")
    assert cli_main(["report", "--in", out, "--out", rep2]) == 0
    with open(rep2) as f:
        assert "Appendix C" in f.read()
