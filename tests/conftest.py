"""Shared pytest fixtures.

The bench experiment cache (``bench/cache.py``) defaults to
``.bench_cache/`` in the working directory. Point it at a session-scoped
temp dir for the whole test run so tests neither read a developer's warm
store (results are bit-identical either way, but counters and timings
would not be) nor leave one behind in the repo.
"""
import pytest


@pytest.fixture(autouse=True, scope="session")
def _isolated_experiment_cache(tmp_path_factory):
    from repro.bench import cache
    cache.configure(root=str(tmp_path_factory.mktemp("bench_cache")))
    yield
