"""Tests for the vectorized JAX lock-performance machine (core.sim).

Validates machine-level invariants and the paper's quantitative claims:
* lost-update freedom: the shared CS word's final value equals completed
  episodes (mutual exclusion at machine level),
* Table 1: misses/episode == 4 (Reciprocating) and 5 (CLH), constant in T;
  Ticket's scales with T (global spinning),
* Fig. 1 ordering at high contention: Reciprocating beats MCS/CLH/Ticket,
* bounded bypass on the machine's admission log.
"""
import jax
import numpy as np
import pytest

from repro.core.locks.programs import PROGRAMS
from repro.core.sim.api import bench_lock
from repro.core.sim.machine import CostModel, run_machine

ALGS = sorted(PROGRAMS)


@pytest.mark.parametrize("name", ALGS)
def test_no_lost_updates(name):
    """With a shared-PRNG CS, mem[CS] must equal completed episodes
    (within the <=T threads still inside at the horizon)."""
    T = 6
    prog = PROGRAMS[name](T, ncs_max=0, cs_shared=True)
    s = jax.jit(lambda: run_machine(prog, T, 8000, CostModel()))()
    cs_val = int(s.mem[4])
    eps = int(s.episodes.sum())
    assert eps > 50, f"{name}: no progress"
    assert eps - T <= cs_val <= eps + T, (name, cs_val, eps)


@pytest.mark.parametrize("name,expect", [("reciprocating", 4), ("clh", 5)])
def test_table1_misses_per_episode(name, expect):
    """Paper Table 1 / §8(C): coherence misses per contended episode."""
    r = bench_lock(name, 10, n_steps=20_000, cs_shared=False,
                   cost=CostModel(n_nodes=1), n_replicas=2)
    assert abs(r.miss_per_episode - expect) < 0.35, r.miss_per_episode


def test_ticket_misses_scale_with_threads():
    r4 = bench_lock("ticket", 4, n_steps=12_000, cs_shared=False,
                    cost=CostModel(n_nodes=1), n_replicas=2)
    r12 = bench_lock("ticket", 12, n_steps=30_000, cs_shared=False,
                     cost=CostModel(n_nodes=1), n_replicas=2)
    assert r12.miss_per_episode > r4.miss_per_episode + 4   # O(T) growth


def test_queue_locks_constant_misses():
    for name in ("reciprocating", "clh", "mcs"):
        r4 = bench_lock(name, 4, n_steps=12_000, cs_shared=False,
                        cost=CostModel(n_nodes=1), n_replicas=2)
        r12 = bench_lock(name, 12, n_steps=30_000, cs_shared=False,
                         cost=CostModel(n_nodes=1), n_replicas=2)
        assert abs(r12.miss_per_episode - r4.miss_per_episode) < 1.0, name


def test_fig1_throughput_ordering_high_contention():
    """At T=16 under maximal contention, Reciprocating leads; Ticket and
    TTAS trail the queue locks (paper Fig. 1a)."""
    res = {n: bench_lock(n, 16, n_steps=30_000, n_replicas=2)
           for n in ("reciprocating", "mcs", "clh", "ticket", "ttas")}
    thr = {n: r.throughput for n, r in res.items()}
    assert thr["reciprocating"] > thr["mcs"]
    assert thr["reciprocating"] > thr["clh"]
    assert thr["reciprocating"] > thr["ticket"] * 1.5
    assert min(thr["mcs"], thr["clh"]) > thr["ttas"]


def test_machine_admission_fairness_bound():
    """Paper §9.2: under sustained contention the admission schedule is
    bimodal with worst-case 2x long-term unfairness; and no thread starves
    (every thread appears regularly in the admission log).

    (The strict bounded-bypass <=1 property is op-level verified in
    test_lock_properties.py; on the *timed* machine a releasing thread pays
    ~3 miss latencies before re-arriving, so admission gaps of 3-4 between
    its turns are legitimate, not bypasses.)"""
    T = 6
    prog = PROGRAMS["reciprocating"](T, ncs_max=0, cs_shared=False)
    s = jax.jit(lambda: run_machine(prog, T, 30_000, CostModel()))()
    log = np.asarray(s.adm_log)
    cnt = int(s.adm_cnt)
    assert cnt >= len(log)          # ring filled
    seq = log.tolist()
    counts = [seq.count(t) for t in range(T)]
    assert min(counts) > 0
    assert max(counts) / min(counts) <= 2.5     # ~2x bimodal (§9.2)
    # anti-starvation: max gap between consecutive turns of any thread is
    # bounded by a small multiple of the population
    for t in range(T):
        idx = [i for i, x in enumerate(seq) if x == t]
        gaps = [b - a for a, b in zip(idx, idx[1:])]
        assert max(gaps) <= 4 * T, (t, max(gaps))


def test_numa_remote_misses():
    """Reciprocating's remote misses/episode stay ~2 (Table 1: xchg on the
    lock word + handoff store); Ticket's scale with threads."""
    rl = bench_lock("reciprocating", 12, n_steps=30_000, cs_shared=False,
                    cost=CostModel(n_nodes=2), n_replicas=2)
    tk = bench_lock("ticket", 12, n_steps=30_000, cs_shared=False,
                    cost=CostModel(n_nodes=2), n_replicas=2)
    assert rl.remote_per_episode < 3.0
    assert tk.remote_per_episode > rl.remote_per_episode + 2


def test_uncontended_latency():
    """Single thread: every algorithm completes episodes without misses
    beyond the first (everything stays in its cache)."""
    for name in ALGS:
        r = bench_lock(name, 1, n_steps=4000, n_replicas=1,
                       cost=CostModel(n_nodes=1))
        assert r.episodes > 100, name
        assert r.miss_per_episode < 0.5, (name, r.miss_per_episode)
