"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
all against the pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property tests degrade to fixed parametrization
    HAVE_HYPOTHESIS = False

from repro.kernels import ref as REF
from repro.kernels.flash_attention import (
    count_kv_fetches, serpentine_savings,
)
from repro.kernels.ops import flash_attention, ssd_scan

KEY = jax.random.PRNGKey(7)


def _qkv(B, H, KV, Sq, Sk, hd, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, KV, Sk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, KV, Sk, hd), jnp.float32).astype(dtype)
    return q, k, v


FA_CASES = [
    # B, H, KV, Sq,  Sk,  hd, causal, window, schedule
    (1, 2, 2, 128, 128, 64, True, 0, "serpentine"),
    (2, 4, 2, 256, 256, 64, True, 0, "serpentine"),
    (2, 4, 2, 256, 256, 64, True, 0, "ascending"),
    (1, 4, 1, 128, 512, 128, False, 0, "serpentine"),   # cross/enc, MQA
    (1, 2, 2, 192, 320, 80, True, 0, "serpentine"),     # ragged, hd=80
    (1, 2, 2, 384, 384, 64, True, 128, "serpentine"),   # sliding window
    (1, 20, 20, 128, 128, 64, False, 0, "serpentine"),  # whisper-like MHA
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    B, H, KV, Sq, Sk, hd, causal, window, sched = case
    q, k, v = _qkv(B, H, KV, Sq, Sk, hd, dtype)
    out = flash_attention(q, k, v, causal, window, sched)
    want = REF.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_schedules_bit_identical():
    """Online softmax is order-invariant: serpentine == ascending."""
    q, k, v = _qkv(2, 4, 4, 256, 256, 64, jnp.float32)
    a = flash_attention(q, k, v, True, 0, "ascending")
    b = flash_attention(q, k, v, True, 0, "serpentine")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_flash_attention_grad_matches_ref():
    q, k, v = _qkv(1, 2, 2, 128, 128, 64, jnp.float32)

    def f_kernel(q, k, v):
        return (flash_attention(q, k, v, True, 0, "serpentine") ** 2).sum()

    def f_ref(q, k, v):
        return (REF.attention_ref(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3,
                                   rtol=1e-3)


if HAVE_HYPOTHESIS:
    def _serp_cases(f):
        return settings(max_examples=10, deadline=None)(
            given(nq=st.integers(1, 40), nkv=st.integers(1, 40))(f))
else:
    _serp_cases = pytest.mark.parametrize(
        "nq,nkv", [(1, 1), (1, 40), (40, 1), (2, 2), (32, 8), (40, 40)])


@_serp_cases
def test_serpentine_always_saves(nq, nkv):
    """Structural property: the reciprocating schedule never fetches more
    KV blocks than ascending, and saves exactly (n_q - 1) interior-boundary
    fetches when n_kv > 1."""
    asc = count_kv_fetches(nq, nkv, "ascending")
    ser = count_kv_fetches(nq, nkv, "serpentine")
    assert ser <= asc
    if nkv > 1:
        assert asc - ser == nq - 1
        assert asc == nq * nkv
    else:   # single KV block stays resident under either schedule
        assert asc == ser == 1


def test_serpentine_savings_report():
    s = serpentine_savings(32, 8)
    assert 0.1 < s["saved_fraction"] < 0.13   # (32-1)/256


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
SSD_CASES = [
    # B, S, H, P, N, chunk
    (1, 128, 4, 32, 16, 32),
    (2, 256, 8, 64, 64, 64),
    (1, 256, 24, 64, 128, 128),   # mamba2-130m-like
    (2, 192, 2, 16, 8, 64),       # ragged chunk count
]


def _ssd_inputs(B, S, H, Pd, N, dtype=jnp.float32):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, Pd), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    a_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.5
    bm = jax.random.normal(ks[3], (B, S, N), jnp.float32).astype(dtype)
    cm = jax.random.normal(ks[4], (B, S, N), jnp.float32).astype(dtype)
    return x, dt.astype(dtype), a_log, bm, cm


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_matches_oracle(case):
    B, S, H, Pd, N, chunk = case
    if S % chunk:
        pytest.skip("chunk must divide S for the kernel")
    x, dt, a_log, bm, cm = _ssd_inputs(B, S, H, Pd, N)
    out = ssd_scan(x, dt, a_log, bm, cm, chunk)
    want = REF.ssd_ref(x, dt, a_log, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_ssd_oracle_matches_sequential():
    """The chunked oracle itself equals the token-by-token recurrence."""
    x, dt, a_log, bm, cm = _ssd_inputs(1, 64, 4, 16, 8)
    a = REF.ssd_ref(x, dt, a_log, bm, cm, chunk=16)
    b = REF.ssd_ref_sequential(x, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)


if HAVE_HYPOTHESIS:
    def _chunk_cases(f):
        return settings(max_examples=8, deadline=None)(
            given(chunk=st.sampled_from([16, 32, 64, 128]))(f))
else:
    _chunk_cases = pytest.mark.parametrize("chunk", [16, 32, 64, 128])


@_chunk_cases
def test_ssd_chunk_invariance(chunk):
    """Result must not depend on the chunking (state handoff correctness)."""
    x, dt, a_log, bm, cm = _ssd_inputs(1, 128, 4, 32, 16)
    a = ssd_scan(x, dt, a_log, bm, cm, chunk)
    b = REF.ssd_ref_sequential(x, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4,
                               rtol=3e-4)
