"""Fleet-gateway tier: radix prefix tree, eviction coherence, routing.

The fleet tier (SERVING.md §8) hinges on one global invariant: the
radix prefix tree may *over*-advertise (a replica listed for a prefix
it has since evicted costs a cache miss) but after the pool's
``evict_callback`` has fired it must never *under*-withdraw (a stale
advertisement surviving eviction would route a request to a replica
that serves garbage). These tests pin that down:

* property suite (hypothesis when available, pinned parametrization
  otherwise) for the tree — insert/match round-trips, longest-prefix
  match vs a brute-force oracle, eviction leaves no dangling replica
  refs and prunes every empty node;
* eviction-coherence regression — a routed request whose advertised
  prefix was LRU-evicted from the replica pool degrades to a prefill
  miss, never a stale-block read;
* router/gateway behaviour — every policy drains the seeded trace,
  prefix routing beats random on global hit rate, the O(requests)
  bookkeeping bound holds, and backpressure respects the dispatch
  window.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property tests degrade to fixed parametrization
    HAVE_HYPOTHESIS = False

from repro.serve.gateway import ROUTERS, FleetGateway, catalogue
from repro.serve.kv_cache import PagedKVPool
from repro.serve.prefix_tree import RadixPrefixTree
from repro.serve.traces import TraceRequest, TraceSpec, generate

BT = 4      # block_tokens used throughout the tree tests


# --- brute-force oracle -------------------------------------------------------

class OracleTree:
    """Reference model: a dict of advertised (replica, prefix-run) pairs,
    no sharing, no pruning — O(everything), obviously correct."""

    def __init__(self, block_tokens: int):
        self.bt = block_tokens
        self.runs: set = set()      # (replica, blocks-tuple prefix chain)

    def _blocks(self, tokens):
        toks = list(tokens)
        return tuple(tuple(toks[j * self.bt:(j + 1) * self.bt])
                     for j in range(len(toks) // self.bt))

    def insert(self, tokens, replica):
        blocks = self._blocks(tokens)
        for k in range(1, len(blocks) + 1):
            self.runs.add((replica, blocks[:k]))

    def match(self, tokens) -> dict:
        blocks = self._blocks(tokens)
        out = {}
        for rep, run in self.runs:
            if run == blocks[:len(run)]:
                out[rep] = max(out.get(rep, 0), len(run))
        return out

    def evict_prefix(self, tokens, depth, replica):
        """Withdraw ``replica`` from the depth-``depth`` prefix of
        ``tokens`` and everything below it (mirror of subtree evict)."""
        victim = self._blocks(tokens)[:depth]
        self.runs = {(rep, run) for rep, run in self.runs
                     if not (rep == replica and run[:depth] == victim
                             and len(run) >= depth)}


def _apply_ops(ops):
    """Drive tree + oracle through an op list; cross-check after every
    step. Ops: ('insert', tokens, replica) | ('evict', tokens, depth,
    replica) | ('match', tokens)."""
    tree = RadixPrefixTree(BT)
    oracle = OracleTree(BT)
    chains: dict = {}           # tokens-tuple -> chain node ids
    for op in ops:
        if op[0] == "insert":
            _, tokens, rep = op
            chains[tuple(tokens)] = tree.insert(tokens, rep)
            oracle.insert(tokens, rep)
        elif op[0] == "evict":
            _, tokens, depth, rep = op
            chain = chains.get(tuple(tokens), [])
            if depth <= len(chain):
                tree.evict(chain[depth - 1], rep)
                oracle.evict_prefix(tokens, depth, rep)
        tree.check()
        for probe_tokens in set(chains) | {tuple(op[1])}:
            assert tree.match(list(probe_tokens)) == \
                oracle.match(probe_tokens), (op, probe_tokens)
    return tree


def _gen_ops(rng, n_ops):
    """Random op list over a tiny alphabet so prefixes collide often."""
    ops = []
    pool = [list(rng.integers(0, 3, size=int(rng.integers(0, 5)) * BT))
            for _ in range(6)]
    for _ in range(n_ops):
        tokens = pool[int(rng.integers(len(pool)))]
        rep = int(rng.integers(0, 3))
        if rng.random() < 0.6 or not ops:
            ops.append(("insert", tokens, rep))
        else:
            depth = int(rng.integers(1, max(len(tokens) // BT, 1) + 1))
            ops.append(("evict", tokens, depth, rep))
    return ops


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(1, 40))
    def test_tree_matches_oracle(seed, n_ops):
        _apply_ops(_gen_ops(np.random.default_rng(seed), n_ops))

else:

    @pytest.mark.parametrize("seed", range(40))
    def test_tree_matches_oracle(seed):
        _apply_ops(_gen_ops(np.random.default_rng(seed), 40))


def test_tree_insert_match_roundtrip():
    tree = RadixPrefixTree(BT)
    toks = list(range(3 * BT))
    ids = tree.insert(toks, replica=1)
    assert len(ids) == 3
    assert tree.match(toks) == {1: 3}
    # partial trailing block is never indexed
    assert tree.insert(list(range(BT + 2)), replica=2) == [ids[0]]
    assert tree.match(toks) == {1: 3, 2: 1}
    # chain ids are stable across re-insertion (content addressing)
    assert tree.insert(toks, replica=1) == ids
    tree.check()


def test_tree_match_requires_contiguous_run():
    """A replica holding blocks 0 and 2 but not 1 matches depth 1: a
    prefix run must be contiguous from the root."""
    tree = RadixPrefixTree(BT)
    toks = list(range(3 * BT))
    ids = tree.insert(toks, replica=0)
    tree.insert(toks, replica=1)        # keeps the chain alive
    tree.evict(ids[1], replica=0)       # 0 loses block 1 (and 2: subtree)
    assert tree.match(toks) == {0: 1, 1: 3}


def test_tree_evict_prunes_and_leaves_no_dangling_refs():
    tree = RadixPrefixTree(BT)
    toks = list(range(4 * BT))
    ids = tree.insert(toks, replica=0)
    assert tree.n_nodes == 4
    # evicting the root block withdraws the whole chain and prunes it
    assert tree.evict(ids[0], replica=0)
    assert tree.n_nodes == 0
    assert tree.match(toks) == {}
    tree.check()
    # idempotent: the node ids are gone, a second evict is a no-op
    assert not tree.evict(ids[0], replica=0)
    # unknown / non-tree keys (pool decode-churn) are ignored
    assert not tree.evict(("decode", 7), replica=0)


def test_tree_evict_keeps_other_replicas():
    tree = RadixPrefixTree(BT)
    toks = list(range(2 * BT))
    ids = tree.insert(toks, replica=0)
    tree.insert(toks, replica=1)
    tree.evict(ids[0], replica=0)
    assert tree.match(toks) == {1: 2}
    assert tree.n_nodes == 2            # still live for replica 1
    tree.drop_replica(1)
    assert tree.n_nodes == 0
    tree.check()


# --- eviction coherence (pool <-> tree) ---------------------------------------

def test_pool_evict_callback_fires_on_lru_eviction():
    dropped = []
    pool = PagedKVPool(4, evict_callback=dropped.append)
    pool.insert("a", 2)
    pool.insert("b", 2)
    pool.insert("c", 1)                 # evicts the LRU "a" block
    assert dropped == [("a", 0)]
    pool.check()


def test_evicted_prefix_degrades_to_miss_never_stale():
    """The regression the fleet tier exists to prevent: replica 0's pool
    LRU-evicts a tenant prefix; the tree withdraws the advertisement; a
    request routed afterwards sees a prefill MISS on that replica (and
    the router no longer prefers it) — never a hit against blocks the
    pool has dropped."""
    tree = RadixPrefixTree(BT)
    pool = PagedKVPool(4, evict_callback=lambda k: tree.evict(k[0], 0))
    toks = list(range(2 * BT))
    chain = tree.insert(toks, replica=0)
    for nid in chain:
        pool.insert(nid, 1)
    assert tree.match(toks) == {0: 2}
    assert all(pool.hit_fraction(nid, 1) == 1.0 for nid in chain)
    # unrelated churn forces LRU eviction of the tenant's root block
    pool.insert("churn1", 2)
    pool.insert("churn2", 2)
    # coherence: the tree withdrew replica 0 the moment the pool dropped
    # the block — the router will not prefer replica 0 for this tenant
    assert tree.match(toks) == {}
    # and an already-routed request probing the old chain sees a miss
    assert pool.hit_fraction(chain[0], 1) == 0.0
    pool.check()


def test_gateway_advertised_then_evicted_prefix_is_clean_miss():
    """End-to-end: run a trace that overflows the (tiny) per-replica
    pools. Every prefill hit the executors count must be backed by
    pool-resident blocks at admission time; total hit rate stays below
    1 and the run completes (stale reads would surface as hits after
    the tree withdrew the replica, or as pool.check() violations)."""
    gw = FleetGateway(n_replicas=2, router="prefix", max_slots=4,
                      pool_blocks=24, block_tokens=16, seed=0)
    s = gw.run(generate(TraceSpec(n_requests=300, n_tenants=40, seed=5)))
    assert s["n"] == 300
    assert 0.0 <= s["hit_rate"] < 1.0
    for rep in gw.replicas:
        rep.pool.check()
    gw.tree.check()
    # the tree only advertises what eviction has not withdrawn: every
    # advertised node id must still be a live tree node
    assert gw.tree.stats.evictions > 0      # the pools really churned


# --- routers / gateway --------------------------------------------------------

def _tiny_trace(n=240, seed=11):
    return generate(TraceSpec(n_requests=n, n_tenants=24, seed=seed))


@pytest.mark.parametrize("router", sorted(ROUTERS))
def test_every_router_drains_the_trace(router):
    gw = FleetGateway(n_replicas=3, router=router, max_slots=4,
                      pool_blocks=64, seed=2)
    s = gw.run(_tiny_trace())
    assert s["n"] == 240
    assert s["router"] == router
    assert s["goodput_tok_per_step"] > 0
    assert s["load_imbalance"] >= 1.0
    # every request was dispatched somewhere real
    assert sum(gw.stats.per_replica) == 240


def test_unknown_router_rejected():
    with pytest.raises(ValueError, match="unknown router"):
        FleetGateway(router="nope")


def test_catalogue_matches_registry():
    assert [name for name, _ in catalogue()] == list(ROUTERS)


def test_bookkeeping_is_linear_in_requests():
    """The satellite micro-assert, unit-sized: one heap pop + one
    retirement per request, independent of trace length or policy."""
    for n in (60, 240):
        gw = FleetGateway(n_replicas=2, router="round_robin", max_slots=4,
                          pool_blocks=64, seed=3)
        gw.run(_tiny_trace(n=n))
        assert sum(r.core.bookkeeping_ops for r in gw.replicas) == 2 * n


def test_prefix_routing_beats_random_on_hit_rate():
    """The suite's headline claim at unit scale, same seeds both sides."""
    def run(router):
        gw = FleetGateway(n_replicas=4, router=router, max_slots=8,
                          pool_blocks=96, seed=1)
        return gw.run(generate(TraceSpec(n_requests=2000, n_tenants=80,
                                         seed=9)))
    assert run("prefix")["hit_rate"] > run("random")["hit_rate"]


def test_dispatch_window_backpressure():
    """The router never overfills a replica: backlog stays within the
    dispatch window while the router still holds queued requests."""
    gw = FleetGateway(n_replicas=2, router="least_loaded", max_slots=2,
                      pool_blocks=64, queue_depth=2, seed=4)
    # a single burst far bigger than the fleet's total window
    reqs = [TraceRequest(rid=i, arrival=1.0, tenant=0,
                         tokens=np.arange(32, dtype=np.int32),
                         prompt_tokens=32, shared_tokens=32,
                         decode_tokens=8)
            for i in range(40)]
    for r in reqs:
        gw.router.submit(r)
    for _ in range(3):
        gw.step()
        for rep in gw.replicas:
            assert rep.core.backlog <= gw.window
    assert len(gw.router) > 0           # backpressure actually engaged
    while gw.has_work():
        gw.step()
    assert gw.stats.n == 40


def test_trace_generator_is_sorted_seeded_and_bounded():
    spec = TraceSpec(n_requests=500, seed=21)
    a = list(generate(spec))
    b = list(generate(TraceSpec(n_requests=500, seed=21)))
    assert len(a) == 500
    arr = [r.arrival for r in a]
    assert arr == sorted(arr)
    assert [r.rid for r in a] == [r.rid for r in b]
    assert [r.tenant for r in a] == [r.tenant for r in b]
    assert all(r.decode_tokens >= 1 for r in a)
    lo, hi = spec.shared_blocks
    for r in a[:50]:
        assert lo * spec.block_tokens <= r.shared_tokens \
            <= hi * spec.block_tokens
        assert r.prompt_tokens == len(r.tokens)
        # shared prefix really is the tenant system prompt
        same = [q for q in a[:50] if q.tenant == r.tenant]
        for q in same:
            n = min(r.shared_tokens, q.shared_tokens)
            assert np.array_equal(r.tokens[:n], q.tokens[:n])
