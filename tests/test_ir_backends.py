"""The IR → two-backend pipeline (ISSUE 10 tentpole).

Four property groups:

* **Golden pinning** — sim output lowered through ``core/locks/ir.py``
  is bit-identical to the pre-IR one-shot compiler. The digests below
  were captured from the pre-refactor pipeline (full ``MachineState``,
  field-declaration order) for every spec in the zoo plus deeper/NUMA
  settings; any drift in the lowering, the scaffolding injection, or
  the machine shows up as a digest mismatch.
* **IR surface** — ``lower_spec`` metadata (labels/phases/release pc),
  the ``OP_TABLE`` contract, and the ``compile_spec`` façade.
* **Backend agreement** — the sim under a uniform cost model dispatches
  exactly the Pallas kernel's round-robin op schedule, so admission
  order and per-thread CS counts must agree across backends.
* **Pallas semantics** — mutual-exclusion stress (in-kernel guard, zero
  collisions), and the unified ``Atomics`` protocol host + device.
"""
import hashlib

import numpy as np
import pytest

from repro.core.locks import ir as irmod
from repro.core.locks.compile import compile_spec
from repro.core.locks.ir import OP_TABLE, LockIR, lower_spec, to_sim_program
from repro.core.locks.programs import PROGRAMS
from repro.core.locks.specs import SPECS
from repro.core.sim import machine as M
from repro.core.sim.machine import CostModel, run_machine

# --- golden pinning -----------------------------------------------------------

# digest = sha256 over every MachineState field (declaration order,
# name + raw bytes), truncated to 16 hex chars. Captured pre-refactor.
GOLDEN = {
    "reciprocating|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "e2fc56ee3d17fb6f",
    "ticket|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "b42c869a2ca1cca5",
    "retrograde|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "79960f2ce27e9c2f",
    "mcs|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "8387d5506d68fc6a",
    "clh|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "cae27353224a9dc9",
    "hemlock|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "83eeeeb403745a43",
    "ttas|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "51eefc194c8050d8",
    "anderson|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "0843d215e9932d04",
    "hapax|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "ce0f7386390b478a",
    "fissile|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "287a7bdc2d709441",
    "spin_then_park|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "9210351668cdf6fa",
    "reciprocating_abortable|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "c6802f617dbac80a",
    "mcs_timeout|T=2|ncs=0|cs=True|steps=400|seed=0|default":
        "8f001e3d0607a9db",
    "reciprocating|T=3|ncs=5|cs=ro|steps=500|seed=1|uniform":
        "39ae02e13b9e5305",
    "hapax|T=4|ncs=17|cs=True|steps=800|seed=3|default":
        "54b5eb92cc257a1f",
    "spin_then_park|T=4|ncs=17|cs=True|steps=800|seed=3|default":
        "f20fa9e6637b559d",
    "mcs_timeout|T=3|ncs=5|cs=ro|steps=500|seed=1|uniform":
        "a7764ebca80d07ef",
}

_CMS = {"default": CostModel(),
        "uniform": CostModel(hit=1, local_miss=1, remote_miss=1)}


def _digest(state) -> str:
    h = hashlib.sha256()
    for f in state._fields:
        h.update(f.encode())
        h.update(np.asarray(getattr(state, f)).tobytes())
    return h.hexdigest()[:16]


def _golden_cases():
    for key, want in GOLDEN.items():
        name, Ts, ncss, css, stepss, seeds, cm = key.split("|")
        yield pytest.param(
            name, int(Ts[2:]), int(ncss[4:]),
            True if css[3:] == "True" else css[3:],
            int(stepss[6:]), int(seeds[5:]), cm, want, id=key)


@pytest.mark.parametrize(
    "name,T,ncs,cs,steps,seed,cm,want", list(_golden_cases()))
def test_sim_through_ir_bit_identical(name, T, ncs, cs, steps, seed, cm,
                                      want):
    prog = PROGRAMS[name](T, ncs_max=ncs, cs_shared=cs)
    s = run_machine(prog, T, steps, cm=_CMS[cm], seed=seed)
    assert _digest(s) == want, (
        f"{name}: sim output through the IR drifted from the "
        "pre-refactor compiler")


def test_golden_covers_every_spec():
    pinned = {k.split("|")[0] for k in GOLDEN}
    assert pinned == set(SPECS), "every spec in the zoo must be pinned"


# --- IR surface ---------------------------------------------------------------

def test_lower_spec_metadata():
    ir = lower_spec(SPECS["reciprocating"], 4, name="reciprocating")
    assert isinstance(ir, LockIR)
    labels = dict(ir.labels)
    assert labels["ncs"] == 0
    assert ir.phases[0] == "ncs" and ir.phases[-1] == "cs"
    assert len(ir.phases) == ir.n_handlers
    assert ir.cs2_pc == ir.n_handlers - 1
    assert ir.phases[ir.release_pc] == "release"
    assert ir.label_of(0) == "ncs"
    # the façade produces the same Program the IR wraps
    prog = to_sim_program(ir)
    facade = compile_spec(SPECS["reciprocating"], 4, name="reciprocating")
    assert prog.n_mem == facade.n_mem and prog.home == facade.home
    assert len(prog.handlers) == len(facade.handlers)


def test_op_table_matches_machine_contract():
    assert set(OP_TABLE) == {
        M.NOP, M.LOAD, M.STORE, M.XCHG, M.CAS, M.FAA, M.SPIN_EQ,
        M.SPIN_NE, M.DELAY, M.PARK_EQ, M.PARK_EQ_TIMEOUT,
        M.PARK_NE_TIMEOUT}
    assert OP_TABLE[M.CAS].result == "old2ok"
    assert OP_TABLE[M.CAS].is_store and OP_TABLE[M.CAS].is_load
    assert OP_TABLE[M.SPIN_EQ].is_wait and not OP_TABLE[M.SPIN_EQ].is_store
    assert OP_TABLE[M.PARK_EQ_TIMEOUT].result == "old2ok"
    assert not OP_TABLE[M.DELAY].is_load


def test_ir_fingerprintable():
    # bench/cache.py duck-types program_fingerprint over the IR directly
    from repro.bench.cache import program_fingerprint
    ir = lower_spec(SPECS["ticket"], 3, name="ticket")
    fp_ir = program_fingerprint(ir)
    fp_prog = program_fingerprint(to_sim_program(ir))
    assert fp_ir == fp_prog


# --- backend agreement --------------------------------------------------------

AGREE = ("reciprocating", "mcs", "ticket", "hapax")


@pytest.mark.parametrize("alg", AGREE)
def test_backend_agreement(alg):
    """Uniform-cost sim == Pallas round-robin schedule: identical
    admission order and, over the compared prefix, identical per-thread
    CS counts."""
    from repro.core.locks.pallas_backend import run_measured

    T = 3
    prog = PROGRAMS[alg](T, ncs_max=0, cs_shared=True)
    s = run_machine(prog, T, 1_000,
                    cm=CostModel(hit=1, local_miss=1, remote_miss=1),
                    seed=0)
    sim_order = np.asarray(s.adm_log)[:int(s.adm_cnt)].tolist()
    r = run_measured(alg, T, 150, interpret=True)
    assert r.collisions == 0
    pal_order = r.admissions[:r.admission_counts].tolist()
    n = min(len(sim_order), len(pal_order), 48)
    assert n >= 16, f"not enough admissions to compare ({n})"
    assert sim_order[:n] == pal_order[:n], (
        f"{alg}: admission order diverged\n sim {sim_order[:n]}\n "
        f"pallas {pal_order[:n]}")
    assert np.bincount(sim_order[:n], minlength=T).tolist() == \
        np.bincount(pal_order[:n], minlength=T).tolist()


# --- Pallas backend semantics -------------------------------------------------

def test_pallas_mutual_exclusion_stress():
    """The in-kernel guard counts any admit that lands while another
    thread is inside its admit..return window — across a long contended
    run it must stay zero, and every thread must make progress."""
    from repro.core.locks.pallas_backend import run_measured

    r = run_measured("reciprocating", 5, 600, interpret=True, seed=2)
    assert r.collisions == 0
    assert r.episodes > 100
    assert (r.per_thread > 0).all(), f"starved thread: {r.per_thread}"
    # every admitted episode eventually returns to the NCS (one episode
    # may still be in flight at the end of the schedule)
    assert abs(r.returns - r.episodes) <= 1


def test_pallas_timed_lock_runs():
    # a timed-park spec exercises the probe-budget path (PARK_*_TIMEOUT)
    from repro.core.locks.pallas_backend import run_measured

    r = run_measured("mcs_timeout", 3, 200, interpret=True)
    assert r.collisions == 0
    assert r.episodes > 0


def test_measured_result_metrics():
    from repro.core.locks.pallas_backend import run_measured

    r = run_measured("ticket", 2, 100, interpret=True)
    assert r.slices == 200
    assert r.backend == "pallas-interpret"
    assert r.throughput_eps > 0 and r.episodes_per_kslice > 0
    assert r.latency_slices >= 0
    assert r.wall_s > 0 and r.compile_s > 0


def test_backends_catalogue():
    from repro.core.locks.pallas_backend import backends

    rows = backends()
    by = {r["name"]: r for r in rows}
    assert set(by) == {"sim", "pallas-interpret", "pallas-device"}
    assert by["sim"]["available"] is True
    assert by["pallas-interpret"]["available"] is True   # CPU fallback
    for r in rows:
        assert isinstance(r["available"], bool) and r["detail"]


# --- the unified Atomics protocol --------------------------------------------

def test_host_atomics_ref():
    from repro.core.runtime.atomics import AtomicRef, host_atomics

    ref = host_atomics().ref(None)
    assert isinstance(ref, AtomicRef)
    assert ref.load() is None
    assert ref.exchange("a") is None and ref.load() == "a"
    assert ref.compare_exchange("a", "b") and ref.load() == "b"
    assert not ref.compare_exchange("zzz", "c") and ref.load() == "b"
    num = host_atomics().ref(5)
    assert num.fetch_add(3) == 5 and num.load() == 8


def test_pallas_atomics_rmw_contract():
    """The generic traced-kind RMW implements the machine's effect
    table: STORE/XCHG write, FAA adds, CAS writes iff old == expect,
    waits/loads leave the word — all returning the old value."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from repro.core.runtime.atomics import PallasAtomics

    atomics = PallasAtomics(interpret=True)
    ops = jnp.array([
        # (kind, idx, a, b, want_old, want_new)
        [M.LOAD, 0, 0, 0, 10, 10],
        [M.XCHG, 0, 77, 0, 10, 77],
        [M.FAA, 1, 5, 0, 20, 25],
        [M.CAS, 2, 30, 99, 30, 99],     # expect matches -> writes b
        [M.CAS, 3, 0, 55, 40, 40],      # expect misses -> unchanged
        [M.STORE, 1, 1, 0, 25, 1],
        [M.SPIN_EQ, 2, 99, 0, 99, 99],  # waits never write
    ], jnp.int32)

    def kernel(ops_ref, mem_in, mem, olds):
        i = pl.program_id(0)
        kind, idx = ops_ref[i, jnp.int32(0)], ops_ref[i, jnp.int32(1)]
        a, b = ops_ref[i, jnp.int32(2)], ops_ref[i, jnp.int32(3)]
        olds[i] = atomics.rmw(mem, idx, kind, a, b)

    mem0 = jnp.array([10, 20, 30, 40], jnp.int32)
    mem, olds = pl.pallas_call(
        kernel, grid=(ops.shape[0],),
        out_shape=[jax.ShapeDtypeStruct((4,), jnp.int32),
                   jax.ShapeDtypeStruct((ops.shape[0],), jnp.int32)],
        input_output_aliases={1: 0},
        interpret=True,
    )(ops, mem0)
    want = np.asarray(ops)[:, 4]
    assert np.asarray(olds).tolist() == want.tolist()
    assert np.asarray(mem).tolist() == [77, 1, 99, 40]


def test_reciprocating_lock_takes_injected_atomics():
    import threading

    from repro.core.runtime.atomics import HostAtomics
    from repro.core.runtime.reciprocating import ReciprocatingLock

    lock = ReciprocatingLock(atomics=HostAtomics())
    counter = [0]

    def worker():
        for _ in range(200):
            with lock:
                counter[0] += 1

    ts = [threading.Thread(target=worker) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert counter[0] == 800
    assert not lock.locked_hint()


def test_ir_module_all_exports():
    for name in irmod.__all__:
        assert hasattr(irmod, name)
