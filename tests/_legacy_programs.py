"""FROZEN pre-redesign hand-rolled handler tables — differential-test
oracle only.

This is the lock zoo exactly as it existed before the ``LockSpec`` DSL
redesign (``core/locks/dsl.py`` + ``compile.py``). It is kept verbatim so
``tests/test_lock_dsl.py`` can assert that every compiled spec produces
*identical* machine metrics to the original tables on pinned seeds. Do not
edit or extend it; new locks are authored as specs in
``core/locks/specs.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sim.machine import (
    CAS, DELAY, FAA, LOAD, NOP, Program, SPIN_EQ, SPIN_NE, STORE, XCHG,
)

I32 = jnp.int32
CS = 4      # shared critical-section word
BASE = 8    # per-thread element base


def _i(x):
    return jnp.asarray(x, I32)


def _op(kind, addr=0, a=0, b=0):
    return (_i(kind), _i(addr), _i(a), _i(b))


def _ret(regs, pc, op, arrive=False, admit=False, rng=None):
    return (regs, _i(pc), op, jnp.asarray(arrive, bool),
            jnp.asarray(admit, bool), rng)


def _xorshift(r):
    r = r ^ (r << jnp.uint32(13))
    r = r ^ (r >> jnp.uint32(17))
    r = r ^ (r << jnp.uint32(5))
    return r


CS2 = 5     # second shared word (read-only CS profile)


def _cs_mode(cs_shared):
    return cs_shared if isinstance(cs_shared, str) else (
        "rw" if cs_shared else "local")


def _cs1(cs_shared):
    """First CS op. Profiles: "rw" = shared-PRNG advance (MutexBench §7.1);
    "local" = degenerate local CS (Table-1 experiment); "ro" = read-only
    lookups (LevelDB-readrandom analogue, Fig. 3)."""
    mode = _cs_mode(cs_shared)
    if mode == "rw":
        return _op(LOAD, CS, 0, 0)
    if mode == "ro":
        return _op(LOAD, CS, 0, 0)
    return _op(DELAY, 0, 1, 0)


def _cs2(cs_shared, res):
    mode = _cs_mode(cs_shared)
    if mode == "rw":
        return (_i(STORE), _i(CS), res + 1, _i(0))
    if mode == "ro":
        return _op(LOAD, CS2, 0, 0)
    return _op(DELAY, 0, 1, 0)


def _ncs_handler(next_pc, ncs_max):
    def h(t, regs, res, rng):
        rng = _xorshift(rng)
        d = _i(rng % jnp.uint32(max(ncs_max, 1))) * (ncs_max > 0)
        return _ret(regs, next_pc, _op(DELAY, 0, d, 0), rng=rng)
    return h


def _home(n_mem, n_threads, per_thread_bases):
    """home[w]: owning thread for per-thread words, else -1 (node 0)."""
    home = [-1] * n_mem
    for base in per_thread_bases:
        for t in range(n_threads):
            home[base + t] = t
    return tuple(home)


# ---------------------------------------------------------------------------
# Reciprocating (paper Listing 1).  regs: r0=succ, r1=eos
# ---------------------------------------------------------------------------
def reciprocating_program(n_threads: int, ncs_max: int = 0, cs_shared=True) -> Program:
    T = n_threads
    ARR = 0

    def h1(t, regs, res, rng):                    # after NCS: prepare E
        return _ret(regs, 2, _op(STORE, BASE + t, 0, 0), rng=rng)

    def h2(t, regs, res, rng):                    # after prepare: push
        return _ret(regs, 3, _op(XCHG, ARR, BASE + t, 0), rng=rng)

    def h3(t, regs, res, rng):                    # consume tail (doorway)
        E = BASE + t
        uncont = res == 0
        succ = jnp.where(res <= 1, 0, res)        # coerce LOCKEDEMPTY
        regs = regs.at[0].set(jnp.where(uncont, 0, succ))
        regs = regs.at[1].set(jnp.where(uncont, E, 0))
        c1 = _cs1(cs_shared)
        kind = jnp.where(uncont, c1[0], _i(SPIN_NE))
        addr = jnp.where(uncont, c1[1], _i(E))
        a = jnp.where(uncont, c1[2], _i(0))
        pc = jnp.where(uncont, _i(6), _i(4))
        return _ret(regs, pc, (kind, addr, a, _i(0)),
                    arrive=True, admit=uncont, rng=rng)

    def h4(t, regs, res, rng):                    # woke: res = eos from Gate
        succ = regs[0]
        term = succ == res                        # terminus sentinel?
        regs = regs.at[0].set(jnp.where(term, 0, succ))
        regs = regs.at[1].set(jnp.where(term, 1, res))
        return _ret(regs, 6, _cs1(cs_shared), admit=True, rng=rng)

    def h6(t, regs, res, rng):                    # CS: advance shared PRNG
        return _ret(regs, 7, _cs2(cs_shared, res), rng=rng)

    def h7(t, regs, res, rng):                    # release
        succ, eos = regs[0], regs[1]
        has_succ = succ != 0
        kind = jnp.where(has_succ, _i(STORE), _i(CAS))
        addr = jnp.where(has_succ, succ, _i(ARR))
        a = jnp.where(has_succ, eos, eos)         # store eos / CAS expect eos
        b = _i(0)
        pc = jnp.where(has_succ, _i(0), _i(8))
        return _ret(regs, pc, (kind, addr, a, b), rng=rng)

    def h8(t, regs, res, rng):                    # consume CAS old*2+ok
        ok = (res % 2) == 1
        kind = jnp.where(ok, _i(NOP), _i(XCHG))
        addr = jnp.where(ok, _i(0), _i(ARR))
        a = jnp.where(ok, _i(0), _i(1))           # detach -> LOCKEDEMPTY
        pc = jnp.where(ok, _i(0), _i(9))
        return _ret(regs, pc, (kind, addr, a, _i(0)), rng=rng)

    def h9(t, regs, res, rng):                    # res = detached head w
        return _ret(regs, 0, _op(STORE, res, regs[1], 0), rng=rng)

    handlers = (_ncs_handler(1, ncs_max), h1, h2, h3, h4,
                _ncs_handler(1, ncs_max),  # pc5 unused filler
                h6, h7, h8, h9)
    n_mem = BASE + T
    return Program(handlers=handlers, n_mem=n_mem,
                   home=_home(n_mem, T, [BASE]), name="reciprocating")


# ---------------------------------------------------------------------------
# Ticket lock.  regs: r0=my ticket
# ---------------------------------------------------------------------------
def ticket_program(n_threads: int, ncs_max: int = 0, cs_shared=True) -> Program:
    TK, GR = 0, 1

    def h1(t, regs, res, rng):
        return _ret(regs, 2, _op(FAA, TK, 1, 0), rng=rng)

    def h2(t, regs, res, rng):                    # got ticket
        regs = regs.at[0].set(res)
        return _ret(regs, 3, _op(SPIN_EQ, GR, res, 0), arrive=True, rng=rng)

    def h3(t, regs, res, rng):                    # granted
        return _ret(regs, 4, _cs1(cs_shared), admit=True, rng=rng)

    def h4(t, regs, res, rng):
        return _ret(regs, 5, _cs2(cs_shared, res), rng=rng)

    def h5(t, regs, res, rng):                    # release: grant++
        return _ret(regs, 6, _op(LOAD, GR, 0, 0), rng=rng)

    def h6(t, regs, res, rng):
        return _ret(regs, 0, _op(STORE, GR, res + 1, 0), rng=rng)

    handlers = (_ncs_handler(1, ncs_max), h1, h2, h3, h4, h5, h6)
    return Program(handlers=handlers, n_mem=BASE,
                   home=_home(BASE, n_threads, []), name="ticket")


# ---------------------------------------------------------------------------
# Retrograde ticket (paper Listing 7).  regs: r0=my, r1=g-1, r2=hi, r3=tmp
# ---------------------------------------------------------------------------
def retrograde_program(n_threads: int, ncs_max: int = 0, cs_shared=True) -> Program:
    TK, GR, TOP, BS = 0, 1, 2, 3

    def h1(t, regs, res, rng):
        return _ret(regs, 2, _op(FAA, TK, 1, 0), rng=rng)

    def h2(t, regs, res, rng):
        regs = regs.at[0].set(res)
        return _ret(regs, 3, _op(SPIN_EQ, GR, res, 0), arrive=True, rng=rng)

    def h3(t, regs, res, rng):
        return _ret(regs, 4, _cs1(cs_shared), admit=True, rng=rng)

    def h4(t, regs, res, rng):
        return _ret(regs, 5, _cs2(cs_shared, res), rng=rng)

    def h5(t, regs, res, rng):                    # release: g = grant-1
        return _ret(regs, 6, _op(LOAD, GR, 0, 0), rng=rng)

    def h6(t, regs, res, rng):
        regs = regs.at[1].set(res - 1)
        return _ret(regs, 7, _op(LOAD, BS, 0, 0), rng=rng)

    def h7(t, regs, res, rng):                    # res = base
        desc = regs[1] > res                      # still inside entry segment
        kind = jnp.where(desc, _i(STORE), _i(LOAD))
        addr = jnp.where(desc, _i(GR), _i(TOP))
        a = jnp.where(desc, regs[1], _i(0))
        pc = jnp.where(desc, _i(0), _i(8))
        return _ret(regs, pc, (kind, addr, a, _i(0)), rng=rng)

    def h8(t, regs, res, rng):                    # res = hi(top)
        regs = regs.at[2].set(res)
        return _ret(regs, 9, _op(STORE, BS, res, 0), rng=rng)

    def h9(t, regs, res, rng):
        return _ret(regs, 10, _op(LOAD, TK, 0, 0), rng=rng)

    def h10(t, regs, res, rng):                   # res = tmp(ticket)
        regs = regs.at[3].set(res)
        return _ret(regs, 11, _op(STORE, TOP, res - 1, 0), rng=rng)

    def h11(t, regs, res, rng):
        empty = regs[3] == regs[2] + 1            # no waiters
        kind = _i(STORE)
        addr = jnp.where(empty, _i(TOP), _i(GR))
        a = jnp.where(empty, regs[3], regs[3] - 1)
        pc = jnp.where(empty, _i(12), _i(0))
        return _ret(regs, pc, (kind, addr, a, _i(0)), rng=rng)

    def h12(t, regs, res, rng):
        return _ret(regs, 13, _op(STORE, BS, regs[3], 0), rng=rng)

    def h13(t, regs, res, rng):
        return _ret(regs, 0, _op(STORE, GR, regs[3], 0), rng=rng)

    handlers = (_ncs_handler(1, ncs_max), h1, h2, h3, h4, h5, h6, h7, h8,
                h9, h10, h11, h12, h13)
    return Program(handlers=handlers, n_mem=BASE,
                   home=_home(BASE, n_threads, []), name="retrograde")


# ---------------------------------------------------------------------------
# MCS.  next[t] = BASE+t, locked[t] = BASE+T+t.  regs: r0=scratch
# ---------------------------------------------------------------------------
def mcs_program(n_threads: int, ncs_max: int = 0, cs_shared=True) -> Program:
    T = n_threads
    TAIL = 0

    def h1(t, regs, res, rng):
        return _ret(regs, 2, _op(STORE, BASE + t, 0, 0), rng=rng)

    def h2(t, regs, res, rng):
        return _ret(regs, 3, _op(STORE, BASE + T + t, 1, 0), rng=rng)

    def h3(t, regs, res, rng):
        return _ret(regs, 4, _op(XCHG, TAIL, BASE + t, 0), rng=rng)

    def h4(t, regs, res, rng):                    # pred
        uncont = res == 0
        c1 = _cs1(cs_shared)
        kind = jnp.where(uncont, c1[0], _i(STORE))
        addr = jnp.where(uncont, c1[1], res)      # pred.next = me
        a = jnp.where(uncont, c1[2], _i(BASE + t))
        pc = jnp.where(uncont, _i(7), _i(5))
        return _ret(regs, pc, (kind, addr, a, _i(0)),
                    arrive=True, admit=uncont, rng=rng)

    def h5(t, regs, res, rng):
        return _ret(regs, 6, _op(SPIN_EQ, BASE + T + t, 0, 0), rng=rng)

    def h6(t, regs, res, rng):
        return _ret(regs, 7, _cs1(cs_shared), admit=True, rng=rng)

    def h7(t, regs, res, rng):
        return _ret(regs, 8, _cs2(cs_shared, res), rng=rng)

    def h8(t, regs, res, rng):                    # release: read my next
        return _ret(regs, 9, _op(LOAD, BASE + t, 0, 0), rng=rng)

    def h9(t, regs, res, rng):
        has = res != 0
        kind = jnp.where(has, _i(STORE), _i(CAS))
        addr = jnp.where(has, res + T, _i(TAIL))  # succ.locked = 0
        a = jnp.where(has, _i(0), _i(BASE + t))
        b = _i(0)
        pc = jnp.where(has, _i(0), _i(10))
        return _ret(regs, pc, (kind, addr, a, b), rng=rng)

    def h10(t, regs, res, rng):                   # CAS old*2+ok
        ok = (res % 2) == 1
        kind = jnp.where(ok, _i(NOP), _i(SPIN_NE))
        addr = jnp.where(ok, _i(0), _i(BASE + t))
        pc = jnp.where(ok, _i(0), _i(11))
        return _ret(regs, pc, (kind, addr, _i(0), _i(0)), rng=rng)

    def h11(t, regs, res, rng):                   # res = next elem addr
        return _ret(regs, 0, _op(STORE, res + T, 0, 0), rng=rng)

    handlers = (_ncs_handler(1, ncs_max), h1, h2, h3, h4, h5, h6, h7, h8,
                h9, h10, h11)
    n_mem = BASE + 2 * T
    return Program(handlers=handlers, n_mem=n_mem,
                   home=_home(n_mem, T, [BASE, BASE + T]), name="mcs")


# ---------------------------------------------------------------------------
# CLH (Scott 4.14).  nodes at BASE..BASE+T (T+1, circulate).
# regs: r0=my node addr, r1=pred addr.  tail(0) init = dummy BASE+T.
# ---------------------------------------------------------------------------
def clh_program(n_threads: int, ncs_max: int = 0, cs_shared=True) -> Program:
    T = n_threads
    TAIL, HEAD = 0, 1

    def h1(t, regs, res, rng):
        node = jnp.where(regs[0] == 0, _i(BASE + t), regs[0])   # lazy init
        regs = regs.at[0].set(node)
        return _ret(regs, 2, (_i(STORE), node, _i(1), _i(0)), rng=rng)

    def h2(t, regs, res, rng):
        return _ret(regs, 3, (_i(XCHG), _i(TAIL), regs[0], _i(0)), rng=rng)

    def h3(t, regs, res, rng):                    # pred
        regs = regs.at[1].set(res)
        return _ret(regs, 4, (_i(SPIN_EQ), res, _i(0), _i(0)),
                    arrive=True, rng=rng)

    def h4(t, regs, res, rng):                    # store head = my node
        return _ret(regs, 5, (_i(STORE), _i(HEAD), regs[0], _i(0)), rng=rng)

    def h5(t, regs, res, rng):                    # adopt pred node; enter CS
        regs = regs.at[0].set(regs[1])
        return _ret(regs, 6, _cs1(cs_shared), admit=True, rng=rng)

    def h6(t, regs, res, rng):
        return _ret(regs, 7, _cs2(cs_shared, res), rng=rng)

    def h7(t, regs, res, rng):                    # release: load head
        return _ret(regs, 8, _op(LOAD, HEAD, 0, 0), rng=rng)

    def h8(t, regs, res, rng):                    # flag[head] = 0
        return _ret(regs, 0, (_i(STORE), res, _i(0), _i(0)), rng=rng)

    handlers = (_ncs_handler(1, ncs_max), h1, h2, h3, h4, h5, h6, h7, h8)
    n_mem = BASE + T + 1
    # CLH nodes circulate: static homes become wrong over time — exactly the
    # paper's point. Home nodes by original allocation.
    home = list(_home(n_mem, T, [BASE]))
    home[BASE + T] = -1
    return Program(handlers=handlers, n_mem=n_mem, home=tuple(home),
                   name="clh", init_mem=((TAIL, BASE + T),))


# ---------------------------------------------------------------------------
# HemLock.  grant[t] = BASE+t; LOCK_ID = 5.  regs: r0=pred
# ---------------------------------------------------------------------------
def hemlock_program(n_threads: int, ncs_max: int = 0, cs_shared=True) -> Program:
    T = n_threads
    TAIL, LOCK_ID = 0, 5

    def h1(t, regs, res, rng):
        return _ret(regs, 2, _op(XCHG, TAIL, BASE + t, 0), rng=rng)

    def h2(t, regs, res, rng):                    # pred
        uncont = res == 0
        regs = regs.at[0].set(res)
        c1 = _cs1(cs_shared)
        kind = jnp.where(uncont, c1[0], _i(SPIN_EQ))
        addr = jnp.where(uncont, c1[1], res)
        a = jnp.where(uncont, c1[2], _i(LOCK_ID))
        pc = jnp.where(uncont, _i(5), _i(3))
        return _ret(regs, pc, (kind, addr, a, _i(0)),
                    arrive=True, admit=uncont, rng=rng)

    def h3(t, regs, res, rng):                    # ack: grant[pred]=0
        return _ret(regs, 4, (_i(STORE), regs[0], _i(0), _i(0)), rng=rng)

    def h4(t, regs, res, rng):
        return _ret(regs, 5, _cs1(cs_shared), admit=True, rng=rng)

    def h5(t, regs, res, rng):
        return _ret(regs, 6, _cs2(cs_shared, res), rng=rng)

    def h6(t, regs, res, rng):                    # release
        return _ret(regs, 7, _op(CAS, TAIL, BASE + t, 0), rng=rng)

    def h7(t, regs, res, rng):
        ok = (res % 2) == 1
        kind = jnp.where(ok, _i(NOP), _i(STORE))
        addr = jnp.where(ok, _i(0), _i(BASE + t))
        a = jnp.where(ok, _i(0), _i(LOCK_ID))
        pc = jnp.where(ok, _i(0), _i(8))
        return _ret(regs, pc, (kind, addr, a, _i(0)), rng=rng)

    def h8(t, regs, res, rng):                    # wait for ack
        return _ret(regs, 0, _op(SPIN_EQ, BASE + t, 0, 0), rng=rng)

    handlers = (_ncs_handler(1, ncs_max), h1, h2, h3, h4, h5, h6, h7, h8)
    n_mem = BASE + T
    return Program(handlers=handlers, n_mem=n_mem,
                   home=_home(n_mem, T, [BASE]), name="hemlock")


# ---------------------------------------------------------------------------
# TTAS (polite test-and-test-and-set)
# ---------------------------------------------------------------------------
def ttas_program(n_threads: int, ncs_max: int = 0, cs_shared=True) -> Program:
    W = 0

    def h1(t, regs, res, rng):
        return _ret(regs, 2, _op(SPIN_EQ, W, 0, 0), arrive=True, rng=rng)

    def h2(t, regs, res, rng):
        return _ret(regs, 3, _op(XCHG, W, 1, 0), rng=rng)

    def h3(t, regs, res, rng):
        got = res == 0
        c1 = _cs1(cs_shared)
        kind = jnp.where(got, c1[0], _i(SPIN_EQ))
        addr = jnp.where(got, c1[1], _i(W))
        a = jnp.where(got, c1[2], _i(0))
        pc = jnp.where(got, _i(4), _i(2))
        return _ret(regs, pc, (kind, addr, a, _i(0)), admit=got, rng=rng)

    def h4(t, regs, res, rng):
        return _ret(regs, 5, _cs2(cs_shared, res), rng=rng)

    def h5(t, regs, res, rng):
        return _ret(regs, 0, _op(STORE, W, 0, 0), rng=rng)

    handlers = (_ncs_handler(1, ncs_max), h1, h2, h3, h4, h5)
    return Program(handlers=handlers, n_mem=BASE,
                   home=_home(BASE, n_threads, []), name="ttas")


# ---------------------------------------------------------------------------
# Anderson array lock.  slots at BASE+i.  regs: r0=my slot addr
# ---------------------------------------------------------------------------
def anderson_program(n_threads: int, ncs_max: int = 0, cs_shared=True) -> Program:
    T = n_threads
    NXT = 0

    def h1(t, regs, res, rng):
        return _ret(regs, 2, _op(FAA, NXT, 1, 0), rng=rng)

    def h2(t, regs, res, rng):
        slot = BASE + (res % T)
        regs = regs.at[0].set(slot)
        return _ret(regs, 3, (_i(SPIN_EQ), slot, _i(1), _i(0)),
                    arrive=True, rng=rng)

    def h3(t, regs, res, rng):
        return _ret(regs, 4, (_i(STORE), regs[0], _i(0), _i(0)), rng=rng)

    def h4(t, regs, res, rng):
        return _ret(regs, 5, _cs1(cs_shared), admit=True, rng=rng)

    def h5(t, regs, res, rng):
        return _ret(regs, 6, _cs2(cs_shared, res), rng=rng)

    def h6(t, regs, res, rng):                    # release: next slot = 1
        nxt = BASE + ((regs[0] - BASE + 1) % T)
        return _ret(regs, 0, (_i(STORE), nxt, _i(1), _i(0)), rng=rng)

    handlers = (_ncs_handler(1, ncs_max), h1, h2, h3, h4, h5, h6)
    n_mem = BASE + T
    return Program(handlers=handlers, n_mem=n_mem,
                   home=_home(n_mem, T, []), name="anderson",
                   init_mem=((BASE, 1),))


LEGACY_PROGRAMS = {
    "reciprocating": reciprocating_program,
    "ticket": ticket_program,
    "retrograde": retrograde_program,
    "mcs": mcs_program,
    "clh": clh_program,
    "hemlock": hemlock_program,
    "ttas": ttas_program,
    "anderson": anderson_program,
}
